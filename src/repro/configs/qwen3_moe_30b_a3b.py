"""Selectable config ``--arch qwen3-moe-30b`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import QWEN3_MOE_30B as CONFIG

SMOKE = reduced(CONFIG)
