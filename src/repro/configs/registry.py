"""Registry of the 10 assigned architectures (+ reduced smoke variants).

Every entry cites its source; exact dimensions follow the assignment table.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, reduced

GROK_1_314B = ArchConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, d_ff_expert=32768, act="gelu",
    source="hf:xai-org/grok-1")

QWEN3_MOE_30B = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, head_dim=128, d_ff=768, vocab=151936,
    n_experts=128, top_k=8, d_ff_expert=768, act="silu",
    source="hf:Qwen/Qwen3-30B-A3B")

WHISPER_MEDIUM = ArchConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab=51865,
    enc_layers=24, n_frames=1500, act="gelu", tie_embeddings=True,
    source="arXiv:2212.04356 (conv frontend stubbed)")

LLAVA_NEXT_34B = ArchConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480, vocab=64000,
    n_image_tokens=2880, act="silu",
    source="hf:llava-hf/llava-v1.6 (anyres ViT tower stubbed)")

STARCODER2_3B = ArchConfig(
    name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
    n_heads=24, n_kv_heads=2, head_dim=128, d_ff=12288, vocab=49152,
    act="gelu", qkv_bias=True, long_context_window=8192,
    source="arXiv:2402.19173 (GQA, RoPE; SWA variant for 500k serving)")

QWEN2_72B = ArchConfig(
    name="qwen2-72b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=29568, vocab=152064,
    act="silu", qkv_bias=True, long_context_window=8192,
    source="arXiv:2407.10671 (GQA, QKV bias; SWA variant for 500k)")

XLSTM_1_3B = ArchConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304, slstm_every=8,
    source="arXiv:2405.04517 (sLSTM + mLSTM blocks, 7:1)")

NEMOTRON_4_340B = ArchConfig(
    name="nemotron-4-340b", family="dense", n_layers=96, d_model=18432,
    n_heads=96, n_kv_heads=8, head_dim=192, d_ff=73728, vocab=256000,
    act="sq_relu", long_context_window=8192,
    source="arXiv:2402.16819 (GQA, squared-ReLU; SWA variant for 500k)")

ZAMBA2_7B = ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
    ssm_state=64, attn_every=6, long_context_window=4096,
    source="arXiv:2411.15242 (Mamba2 + shared attn block)")

GRANITE_3_2B = ArchConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, head_dim=64, d_ff=8192, vocab=49155,
    act="silu", long_context_window=8192, tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base (SWA variant for 500k)")

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    GROK_1_314B, QWEN3_MOE_30B, WHISPER_MEDIUM, LLAVA_NEXT_34B,
    STARCODER2_3B, QWEN2_72B, XLSTM_1_3B, NEMOTRON_4_340B, ZAMBA2_7B,
    GRANITE_3_2B]}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


def get_reduced(name: str, **kw) -> ArchConfig:
    return reduced(get(name), **kw)
