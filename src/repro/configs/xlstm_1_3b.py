"""Selectable config ``--arch xlstm-1-3b`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import XLSTM_1_3B as CONFIG

SMOKE = reduced(CONFIG)
