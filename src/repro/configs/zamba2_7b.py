"""Selectable config ``--arch zamba2-7b`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import ZAMBA2_7B as CONFIG

SMOKE = reduced(CONFIG)
