"""Selectable config ``--arch starcoder2-3b`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import STARCODER2_3B as CONFIG

SMOKE = reduced(CONFIG)
