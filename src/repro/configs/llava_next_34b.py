"""Selectable config ``--arch llava-next-34b`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import LLAVA_NEXT_34B as CONFIG

SMOKE = reduced(CONFIG)
