"""Selectable config ``--arch whisper-medium`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import WHISPER_MEDIUM as CONFIG

SMOKE = reduced(CONFIG)
