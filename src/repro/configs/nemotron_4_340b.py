"""Selectable config ``--arch nemotron-4-340b`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import NEMOTRON_4_340B as CONFIG

SMOKE = reduced(CONFIG)
