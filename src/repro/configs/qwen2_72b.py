"""Selectable config ``--arch qwen2-72b`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import QWEN2_72B as CONFIG

SMOKE = reduced(CONFIG)
