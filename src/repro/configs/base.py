"""Architecture configuration schema and reduced-variant helper."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One selectable architecture (``--arch <name>``)."""

    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads
    act: str = "silu"           # silu | gelu | sq_relu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # -- MoE ------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_groups: int = 1         # dispatch groups (launcher: data shards)
    # -- SSM (Mamba2) / hybrid -------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    attn_every: int = 0         # hybrid: shared attn block every k ssm layers
    # -- xLSTM -----------------------------------------------------------
    slstm_every: int = 0        # 1 sLSTM per this many layers (rest mLSTM)
    # -- encoder-decoder (audio) ------------------------------------------
    enc_layers: int = 0
    n_frames: int = 0           # stub frontend sequence length
    # -- VLM ---------------------------------------------------------------
    n_image_tokens: int = 0     # stub vision tower output length
    # -- attention variants -------------------------------------------------
    sliding_window: int = 0     # 0 = full causal; >0 = banded (sub-quadratic)
    long_context_window: int = 0  # SWA width used ONLY for the long_500k
                                  # serving variant (cfg is otherwise full)
    # -- optimizations (§Perf) -------------------------------------------
    attn_impl: str = "ref"      # "ref" (jnp, XLA-sharded) | "pallas"
                                # (kernels/: flash attention + flash-decode;
                                # interpret-mode on CPU, Mosaic on TPU)
    opt_decode: bool = False    # shard_map flash-decode (beyond-paper)
    expert_split: int = 1       # split each expert's d_ff s-ways so the
                                # (E·s) dim divides the model axis: true
                                # expert-tensor parallelism for grok's 8
                                # experts on a 16-way axis (beyond-paper)
    remat_policy: str = "full"  # "full" (nothing saveable) or "dots"
                                # (save matmul outputs; less recompute,
                                # more resident activations — §Perf)
    # -- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    unroll_layers: bool = False  # Python-loop layers instead of lax.scan
                                 # (roofline delta method: cost_analysis
                                 # counts a while body only once)
    source: str = ""            # paper / model-card citation

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:   # Mamba2 / mLSTM expansion
        return 2 * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def supports_decode(self) -> bool:
        return True             # all assigned archs have a decoder

    def supports_long_context(self) -> bool:
        """Sub-quadratic serving at 500k context (see DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0 \
            or self.long_context_window > 0

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = d * (h + 2 * kv) * hd + h * hd * d
        if self.family == "moe":
            mlp = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
        elif self.act == "silu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "ssm":
            blocks = self.n_layers * self._xlstm_block_params() \
                if self.slstm_every else self.n_layers * self._mamba_params()
        elif self.family == "hybrid":
            blocks = self.n_layers * self._mamba_params() + (attn + mlp)
        elif self.family == "encdec":
            blocks = self.enc_layers * (attn + mlp) + \
                self.n_layers * (2 * attn + mlp)
        else:
            blocks = self.n_layers * (attn + mlp)
        embed = v * d * (1 if self.tie_embeddings else 2)
        return int(blocks + embed)

    def _mamba_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        return d * (2 * di + 2 * n + self.ssm_heads) + di * d

    def _xlstm_block_params(self) -> int:
        d, di = self.d_model, self.d_inner
        return 3 * d * di + di * d + 2 * d * 4

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * (
            self.n_experts * 3 * d * self.d_ff_expert)
        return int(dense + self.n_layers * self.top_k * 3 * d *
                   self.d_ff_expert)


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 128,
            vocab: int = 512) -> ArchConfig:
    """CPU-smoke-test variant of the same family (≤512 wide, 2 layers)."""
    scale = d_model / cfg.d_model
    heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.n_kv_heads, heads))
    while heads % kv:
        kv -= 1
    repl = dict(
        n_layers=n_layers, d_model=d_model, n_heads=heads, n_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=max(32, int(cfg.d_ff * scale)) if cfg.d_ff else 0,
        vocab=vocab, dtype="float32", param_dtype="float32", remat=False,
    )
    if cfg.family == "moe":
        # capacity 8.0 → effectively dropless, so prefill/decode dispatch
        # is batch-shape independent and exactly matches the forward pass
        repl.update(n_experts=4, top_k=min(2, cfg.top_k),
                    d_ff_expert=max(32, int(cfg.d_ff_expert * scale)),
                    capacity_factor=8.0)
    if cfg.family in ("ssm", "hybrid"):
        repl.update(ssm_state=16, ssm_head_dim=32)
    if cfg.attn_every:
        repl.update(attn_every=1, n_layers=2)
    if cfg.slstm_every:
        repl.update(slstm_every=2, n_layers=2)
    if cfg.enc_layers:
        repl.update(enc_layers=n_layers, n_frames=16)
    if cfg.n_image_tokens:
        repl.update(n_image_tokens=8)
    if cfg.sliding_window or cfg.long_context_window:
        repl.update(sliding_window=16, long_context_window=16)
    return dataclasses.replace(cfg, **repl)
