"""Selectable config ``--arch grok-1-314b`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import GROK_1_314B as CONFIG

SMOKE = reduced(CONFIG)
