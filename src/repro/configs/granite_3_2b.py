"""Selectable config ``--arch granite-3-2b`` (see registry for the citation)."""
from repro.configs.base import reduced
from repro.configs.registry import GRANITE_3_2B as CONFIG

SMOKE = reduced(CONFIG)
