"""Synthetic LM data pipeline (deterministic, shardable, CPU-friendly).

Generates a Zipf-distributed token stream with short-range structure (a
first-order Markov chain over a small state space) so models actually have
something learnable — loss decreases measurably within a few hundred steps
on reduced configs (see examples/train_small.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    n_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse-ish Markov transition over hidden states
        self._trans = rng.dirichlet(np.full(self.n_states, 0.25),
                                    size=self.n_states)
        # each state emits from a Zipf-tilted slice of the vocab
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        zipf = 1.0 / ranks
        self._emit = np.stack([
            np.roll(zipf, rng.integers(0, self.vocab)) for _ in
            range(self.n_states)])
        self._emit /= self._emit.sum(axis=1, keepdims=True)

    def batches(self, *, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            rng = np.random.default_rng((self.seed, step))
            toks = np.empty((self.batch, self.seq_len + 1), np.int32)
            state = rng.integers(0, self.n_states, size=self.batch)
            for t in range(self.seq_len + 1):
                for b in range(self.batch):
                    toks[b, t] = rng.choice(self.vocab,
                                            p=self._emit[state[b]])
                    state[b] = rng.choice(self.n_states,
                                          p=self._trans[state[b]])
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            step += 1


@dataclasses.dataclass
class FastSyntheticLM:
    """Vectorized variant (no per-token Python loop) for bigger batches.

    Keeps the Zipf marginal but models structure as ``next ≈ f(prev)`` with
    noise — cheap to sample yet non-trivial to predict.
    """

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0

    def batches(self, *, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = (1.0 / ranks) / np.sum(1.0 / ranks)
        while True:
            rng = np.random.default_rng((self.seed, 7, step))
            base = rng.choice(self.vocab, size=(self.batch, self.seq_len + 1),
                              p=p)
            # structure: 60 % of positions deterministically derive from the
            # previous token; the rest stay random
            mix = rng.random((self.batch, self.seq_len)) < 0.6
            derived = (base[:, :-1] * 31 + 7) % self.vocab
            base[:, 1:][mix] = derived[mix]
            yield {"tokens": base[:, :-1].astype(np.int32),
                   "labels": base[:, 1:].astype(np.int32)}
            step += 1
