"""Deterministic, seeded fault schedules for the chaos engine (ISSUE 9).

A :class:`FaultSpec` is a declarative bundle of hostile events — edge
crashes, network partitions, jamming windows, correlated cloud
brownouts, DDoS-shaped arrival floods and telemetry-channel chaos —
that :mod:`repro.faults.compile` lowers into *both* backends:

* dense ``FleetSignals`` lanes (``edge_up``/``link_up`` booleans, θ
  overlays added to the ``theta`` channel, bandwidth caps min'd into
  ``bw``, flood arrivals emitted through the shared sink protocol) for
  the compiled tick program, and
* the matching event-oracle models (per-edge outage windows, crash
  windows, θ/bandwidth trace transforms, the same flood arrivals) for
  :class:`repro.sim.engine.Simulator`.

Everything is a frozen dataclass keyed only by scenario seed + per-fault
seed, so a schedule is reproducible bit-for-bit across backends and
across kill/restore of the streaming controller.

This module imports nothing from the rest of the package (stdlib only)
so ``scenarios.spec`` can depend on it without cycles.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


def _check_window(kind: str, start_ms: float, end_ms: float) -> None:
    if start_ms < 0.0:
        raise ValueError(f"{kind}.start_ms must be >= 0, got {start_ms}")
    if end_ms <= start_ms:
        raise ValueError(
            f"{kind} window must satisfy end_ms > start_ms, got "
            f"[{start_ms}, {end_ms})")


@dataclass(frozen=True)
class EdgeCrash:
    """Edge ``edge`` is down on ``[start_ms, end_ms)``.

    While down the edge admits nothing (arrivals re-route cloudward or
    drop, per policy), its queue is flushed as drops at crash time, and
    work stealing / new executions are suspended.  The task that was
    *in flight* at crash time completes — the model is a scheduler
    crash, not a power cut — and the edge restarts with an empty queue.
    """
    edge: int
    start_ms: float
    end_ms: float

    def __post_init__(self) -> None:
        if self.edge < 0:
            raise ValueError(f"EdgeCrash.edge must be >= 0, got {self.edge}")
        _check_window("EdgeCrash", self.start_ms, self.end_ms)


@dataclass(frozen=True)
class Partition:
    """The edge↔cloud link is severed on ``[start_ms, end_ms)``.

    Affects ``edges`` (all edges when ``None``): cloud dispatch is
    parked (tasks wait, exactly like a cloud outage seen from the
    affected edges) and GEMS pool migration across the link halts.
    Edge-local execution continues.
    """
    start_ms: float
    end_ms: float
    edges: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        _check_window("Partition", self.start_ms, self.end_ms)
        if self.edges is not None and any(e < 0 for e in self.edges):
            raise ValueError(f"Partition.edges must be >= 0: {self.edges}")


@dataclass(frozen=True)
class Jamming:
    """RF jamming on ``[start_ms, end_ms)``: the link survives but is
    shaped — a flat ``theta_ms`` penalty is added to cloud latency and
    the cellular bandwidth is capped at ``bw_cap_mbps`` for ``edges``
    (all when ``None``)."""
    start_ms: float
    end_ms: float
    theta_ms: float = 250.0
    bw_cap_mbps: float = 2.0
    edges: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        _check_window("Jamming", self.start_ms, self.end_ms)
        if self.theta_ms < 0.0:
            raise ValueError(f"Jamming.theta_ms must be >= 0: {self.theta_ms}")
        if self.bw_cap_mbps <= 0.0:
            raise ValueError(
                f"Jamming.bw_cap_mbps must be > 0: {self.bw_cap_mbps}")


@dataclass(frozen=True)
class Brownout:
    """Correlated cloud brownout: θ(t) for *every* edge gains a
    trapezoidal overlay ramping to ``theta_ms`` over ``ramp_ms`` on
    ``[start_ms, end_ms)``.  This layers on top of whatever θ model the
    scenario already carries — the DEMS-A estimator has to chase it."""
    start_ms: float
    end_ms: float
    theta_ms: float = 300.0
    ramp_ms: float = 5_000.0

    def __post_init__(self) -> None:
        _check_window("Brownout", self.start_ms, self.end_ms)
        if self.theta_ms < 0.0:
            raise ValueError(
                f"Brownout.theta_ms must be >= 0: {self.theta_ms}")
        if self.ramp_ms < 0.0:
            raise ValueError(f"Brownout.ramp_ms must be >= 0: {self.ramp_ms}")
        if 2.0 * self.ramp_ms > self.end_ms - self.start_ms:
            raise ValueError(
                "Brownout ramps overlap: 2*ramp_ms exceeds the window "
                f"({self.ramp_ms} vs [{self.start_ms}, {self.end_ms}))")


@dataclass(frozen=True)
class Flood:
    """DDoS-shaped arrival flood: ``rate_hz`` extra full-model frames
    per second are injected at ``edges`` (all when ``None``) on
    ``[start_ms, end_ms)``, attributed to a synthetic attacker drone.
    Timing is drawn from a deterministic stream keyed by
    ``(scenario seed, flood seed, edge)`` so both backends see the
    identical flood."""
    start_ms: float
    end_ms: float
    rate_hz: float = 10.0
    edges: Optional[Tuple[int, ...]] = None
    seed: int = 0

    def __post_init__(self) -> None:
        _check_window("Flood", self.start_ms, self.end_ms)
        if self.rate_hz <= 0.0:
            raise ValueError(f"Flood.rate_hz must be > 0: {self.rate_hz}")


@dataclass(frozen=True)
class TelemetryChaos:
    """Lossy at-least-once telemetry channel between the fleet and the
    streaming controller: each event is independently dropped with
    ``drop_p``, duplicated with ``dup_p``, and delayed by up to
    ``max_delay_ms`` with ``reorder_p`` (which reorders it past later
    events).  Consumed by :func:`repro.faults.compile.perturb_telemetry`
    in controller tests — the dense/oracle backends see the ground
    truth, the controller sees the chaos."""
    drop_p: float = 0.0
    dup_p: float = 0.0
    reorder_p: float = 0.0
    max_delay_ms: float = 200.0
    seed: int = 0

    def __post_init__(self) -> None:
        for name in ("drop_p", "dup_p", "reorder_p"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"TelemetryChaos.{name} must be in [0, 1]: {v}")
        if self.max_delay_ms < 0.0:
            raise ValueError(
                f"TelemetryChaos.max_delay_ms must be >= 0: "
                f"{self.max_delay_ms}")


@dataclass(frozen=True)
class FaultSpec:
    """The full deterministic fault schedule for one scenario."""
    crashes: Tuple[EdgeCrash, ...] = ()
    partitions: Tuple[Partition, ...] = ()
    jamming: Tuple[Jamming, ...] = ()
    brownouts: Tuple[Brownout, ...] = ()
    floods: Tuple[Flood, ...] = ()
    telemetry: Optional[TelemetryChaos] = None

    def __post_init__(self) -> None:
        # overlapping crash windows on the same edge are contradictory
        by_edge: dict = {}
        for c in self.crashes:
            by_edge.setdefault(c.edge, []).append((c.start_ms, c.end_ms))
        for edge, wins in by_edge.items():
            wins.sort()
            for (s0, e0), (s1, _) in zip(wins, wins[1:]):
                if s1 < e0:
                    raise ValueError(
                        f"overlapping EdgeCrash windows on edge {edge}: "
                        f"[{s0}, {e0}) and [{s1}, ...)")

    def validate_edges(self, n_edges: int) -> None:
        """Raise if any fault names an edge outside ``range(n_edges)``."""
        for c in self.crashes:
            if c.edge >= n_edges:
                raise ValueError(
                    f"EdgeCrash.edge {c.edge} out of range for "
                    f"{n_edges} edges")
        for group in (self.partitions, self.jamming, self.floods):
            for f in group:
                if f.edges is not None and any(
                        e >= n_edges for e in f.edges):
                    raise ValueError(
                        f"{type(f).__name__}.edges {f.edges} out of range "
                        f"for {n_edges} edges")

    def shifted(self, dt_ms: float) -> "FaultSpec":
        """A copy with every window shifted by ``dt_ms`` (test helper)."""
        def mv(f):
            return dataclasses.replace(
                f, start_ms=f.start_ms + dt_ms, end_ms=f.end_ms + dt_ms)
        return dataclasses.replace(
            self,
            crashes=tuple(mv(c) for c in self.crashes),
            partitions=tuple(mv(p) for p in self.partitions),
            jamming=tuple(mv(j) for j in self.jamming),
            brownouts=tuple(mv(b) for b in self.brownouts),
            floods=tuple(mv(f) for f in self.floods))
