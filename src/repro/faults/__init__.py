"""Chaos engine: deterministic fault schedules for both backends."""
from repro.faults.compile import (bw_cap_fn, crash_windows, edge_up_dense,
                                  flood_events, link_up_dense,
                                  partition_windows, perturb_telemetry,
                                  theta_overlay_fn)
from repro.faults.spec import (Brownout, EdgeCrash, FaultSpec, Flood,
                               Jamming, Partition, TelemetryChaos)

__all__ = [
    "Brownout", "EdgeCrash", "FaultSpec", "Flood", "Jamming", "Partition",
    "TelemetryChaos", "bw_cap_fn", "crash_windows", "edge_up_dense",
    "flood_events", "link_up_dense", "partition_windows",
    "perturb_telemetry", "theta_overlay_fn",
]
