"""Lower a :class:`~repro.faults.spec.FaultSpec` into backend inputs.

Everything here is deliberately *shared* between the two consumers:

* the fleet compiler (:func:`repro.scenarios.compile.compile_fleet`)
  evaluates the overlay/cap callables on the tick grid and merges the
  boolean lanes into ``FleetSignals``;
* the oracle runner wraps the same callables around each edge's
  ``theta_fn``/``bw_fn`` and feeds the window lists to
  :class:`repro.sim.engine.Simulator`.

Because both sides consume the *same* functions and the *same* seeded
event lists, a fault schedule means the identical thing in either
backend — which is what lets the fleet-vs-oracle agreement tests extend
to hostile conditions.
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Sequence, Tuple

import numpy as np

from repro.faults.spec import FaultSpec, TelemetryChaos

# deterministic RNG stream tags (decimal-safe, disjoint from the
# scenario compiler's 0x6275 burst / 0x4A17 jitter / 0x0dde order tags)
_FLOOD_TAG = 0xF10D
_TELEM_TAG = 0x7E1E


def _affects(edges, e: int) -> bool:
    return edges is None or e in edges


def _in_window(t: np.ndarray, start: float, end: float) -> np.ndarray:
    return (t >= start) & (t < end)


# ---------------------------------------------------------------------------
# boolean availability lanes (fleet) / window lists (oracle)
# ---------------------------------------------------------------------------

def edge_up_dense(faults: FaultSpec, times: np.ndarray,
                  n_edges: int) -> np.ndarray:
    """``bool [T, E]`` — False while the edge is crashed."""
    up = np.ones((len(times), n_edges), dtype=bool)
    for c in faults.crashes:
        if c.edge < n_edges:
            up[_in_window(times, c.start_ms, c.end_ms), c.edge] = False
    return up


def link_up_dense(faults: FaultSpec, times: np.ndarray,
                  n_edges: int) -> np.ndarray:
    """``bool [T, E]`` — False while the edge↔cloud link is partitioned."""
    up = np.ones((len(times), n_edges), dtype=bool)
    for p in faults.partitions:
        mask = _in_window(times, p.start_ms, p.end_ms)
        for e in range(n_edges):
            if _affects(p.edges, e):
                up[mask, e] = False
    return up


def crash_windows(faults: FaultSpec,
                  n_edges: int) -> List[Tuple[Tuple[float, float], ...]]:
    """Per-edge sorted ``(start, end)`` crash windows for the oracle."""
    out: List[List[Tuple[float, float]]] = [[] for _ in range(n_edges)]
    for c in faults.crashes:
        if c.edge < n_edges:
            out[c.edge].append((c.start_ms, c.end_ms))
    return [tuple(sorted(w)) for w in out]


def partition_windows(faults: FaultSpec,
                      n_edges: int) -> List[Tuple[Tuple[float, float], ...]]:
    """Per-edge sorted ``(start, end)`` partition windows.

    The oracle models a partition as a per-edge cloud outage with no
    cold-start penalty: dispatch parks, pending tasks wait, and the
    DEMS/GEMS policies see exactly what the fleet's ``link_up`` gate
    produces.
    """
    out: List[List[Tuple[float, float]]] = [[] for _ in range(n_edges)]
    for p in faults.partitions:
        for e in range(n_edges):
            if _affects(p.edges, e):
                out[e].append((p.start_ms, p.end_ms))
    return [tuple(sorted(w)) for w in out]


# ---------------------------------------------------------------------------
# θ overlays and bandwidth caps (array-native; both backends call these)
# ---------------------------------------------------------------------------

def theta_overlay_fn(faults: FaultSpec,
                     edge: int) -> Callable[[float], float]:
    """Added WAN latency (ms) for ``edge`` as an array-native f(t_ms).

    Sum of every jamming window covering the edge (flat penalty) and
    every correlated brownout (trapezoidal ramp, all edges).  Returns a
    plain ``lambda t: 0.0``-equivalent when nothing applies, so wrapping
    is free for fault-free scenarios.
    """
    jams = [j for j in faults.jamming if _affects(j.edges, edge)]
    brs = list(faults.brownouts)

    def fn(t):
        ts = np.asarray(t, dtype=np.float64)
        add = np.zeros_like(ts)
        for j in jams:
            add = add + np.where(
                _in_window(ts, j.start_ms, j.end_ms), j.theta_ms, 0.0)
        for b in brs:
            ramp = max(b.ramp_ms, 1e-9)
            shape = np.minimum(
                np.clip((ts - b.start_ms) / ramp, 0.0, 1.0),
                np.clip((b.end_ms - ts) / ramp, 0.0, 1.0))
            add = add + np.where(
                _in_window(ts, b.start_ms, b.end_ms),
                b.theta_ms * shape, 0.0)
        return add
    return fn


def bw_cap_fn(faults: FaultSpec, edge: int) -> Callable[[float], float]:
    """Bandwidth ceiling (Mbps) for ``edge``, ``+inf`` outside jamming."""
    jams = [j for j in faults.jamming if _affects(j.edges, edge)]

    def fn(t):
        ts = np.asarray(t, dtype=np.float64)
        cap = np.full(ts.shape, np.inf)
        for j in jams:
            cap = np.where(_in_window(ts, j.start_ms, j.end_ms),
                           np.minimum(cap, j.bw_cap_mbps), cap)
        return cap
    return fn


# ---------------------------------------------------------------------------
# DDoS-shaped arrival floods (shared event list → both sinks)
# ---------------------------------------------------------------------------

def flood_events(scenario_seed: int, faults: FaultSpec, n_edges: int,
                 n_models: int, duration_ms: float,
                 n_drones: int = 0) -> List[Tuple[float, int, int, np.ndarray]]:
    """Deterministic flood arrivals as ``(t_ms, drone, edge, order)``.

    One event is one full-model frame (the same unit the benign stream
    emits), attributed to a synthetic attacker drone id past the real
    fleet.  The stream is keyed ``[scenario_seed, 0xF10D, flood_seed,
    edge]`` so both compilers — and a restarted streaming controller —
    draw the identical flood.  Sorted by (time, edge) so sink order is
    deterministic too.
    """
    events: List[Tuple[float, int, int, np.ndarray]] = []
    for i, f in enumerate(faults.floods):
        attacker = n_drones + i
        hi = min(f.end_ms, duration_ms)
        if hi <= f.start_ms:
            continue
        n = int(round(f.rate_hz * (hi - f.start_ms) / 1_000.0))
        for e in range(n_edges):
            if not _affects(f.edges, e):
                continue
            rng = np.random.default_rng(
                [scenario_seed, _FLOOD_TAG, f.seed, e])
            times = np.sort(rng.uniform(f.start_ms, hi, size=n))
            for t in times:
                events.append((float(t), attacker, e,
                               rng.permutation(n_models)))
    events.sort(key=lambda ev: (ev[0], ev[2]))
    return events


# ---------------------------------------------------------------------------
# telemetry-channel chaos (controller tests: drop / duplicate / reorder)
# ---------------------------------------------------------------------------

def perturb_telemetry(events: Sequence, chaos: TelemetryChaos,
                      time_of: Callable[[object], float] = None
                      ) -> List:
    """At-least-once channel simulation over an event sequence.

    Each event is independently dropped (``drop_p``), duplicated
    (``dup_p``) and/or delayed by up to ``max_delay_ms`` (``reorder_p``);
    the surviving deliveries are returned in delivery order (a delayed
    event lands *after* later-sent events — the out-of-order replay the
    controller's at-least-once contract has to absorb).  ``time_of``
    extracts an event's send time (default: ``event[0]``).
    """
    if time_of is None:
        time_of = lambda ev: float(ev[0])   # noqa: E731
    rng = np.random.default_rng([chaos.seed, _TELEM_TAG])
    deliveries: List[Tuple[float, int, object]] = []
    for i, ev in enumerate(events):
        if rng.random() < chaos.drop_p:
            continue
        copies = 2 if rng.random() < chaos.dup_p else 1
        for _ in range(copies):
            delay = (rng.uniform(0.0, chaos.max_delay_ms)
                     if rng.random() < chaos.reorder_p else 0.0)
            deliveries.append((time_of(ev) + delay, i, ev))
    deliveries.sort(key=lambda d: (d[0], d[1]))
    return [ev for _, _, ev in deliveries]
