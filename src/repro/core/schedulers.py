"""Scheduling policies: DEMS family (§5) and the seven baselines (§8.2).

A :class:`Policy` is a small strategy object consulted by the simulator /
serve engine.  It owns *decision logic only* — queues, executors and clocks
live in the runtime (``sim.engine.Simulator`` or ``serve.engine``).

Implemented policies (paper names):

==============  =============================================================
``EDF``         edge-only, earliest-deadline-first
``HPF``         edge-only, highest utility-per-edge-second first
``CLD``         cloud-only (negative-cloud-utility tasks dropped)
``EDF-E+C``     EDF edge queue + FIFO cloud (the paper's E+C baseline)
``SJF-E+C``     shortest-job-first edge + FIFO cloud, accepts γ^C<0 tasks
``SOTA1``       Kalmia[40]+D3[58] adaptation: urgent/non-urgent classes,
                10 % deadline buffer for non-urgent, then offload
``SOTA2``       Dedas[35] adaptation: exec-time priority + ACT comparison
``DEM``         E+C + migration scoring (Eqn 3, §5.2)
``DEMS``        DEM + work stealing with trigger-time cloud queue (§5.3)
``DEMS-A``      DEMS + sliding-window cloud-latency adaptation (§5.4)
``GEMS``        DEMS + QoE window-rate guaranteeing rescheduler (§6, Alg 1)
``GEMS-A``      GEMS + the DEMS-A adaptation
==============  =============================================================
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.task import ModelProfile, Task, migration_score


@dataclasses.dataclass
class CloudAccept:
    """Outcome of offering a task to the cloud scheduler."""

    accept: bool
    trigger: float = 0.0       # earliest dispatch time (trigger-time queue)
    steal_only: bool = False   # parked only so the edge may steal it


@dataclasses.dataclass
class Policy:
    name: str
    use_edge: bool = True
    use_cloud: bool = True
    edge_feasibility_check: bool = True   # reject infeasible edge inserts
    migration: bool = False               # DEM scoring (§5.2)
    stealing: bool = False                # work stealing + trigger times (§5.3)
    adaptive: bool = False                # DEMS-A latency adaptation (§5.4)
    gems: bool = False                    # GEMS window rescheduling (§6)
    gems_budget: bool = False             # GEMS-B (beyond-paper): skip
                                          # rescheduling once the window is
                                          # mathematically unrecoverable
    cloud_accepts_negative: bool = False  # SJF-E+C sends γ^C<0 tasks anyway
    edge_priority: str = "edf"            # "edf" | "hpf" | "sjf"
    sota1: bool = False
    sota2: bool = False
    cloud_margin: float = 50.0            # trigger-time safety margin [ms]
    urgent_deadline: float = 700.0        # SOTA1 urgency threshold [ms]

    # ------------------------------------------------------------------
    # Edge queue ordering
    # ------------------------------------------------------------------
    def edge_key(self, task: Task) -> float:
        if self.edge_priority == "edf":
            return task.sched_deadline          # §5.1: priority t'_j + δ_i
        if self.edge_priority == "hpf":
            return -task.model.hpf_rank         # §8.2 greedy utility rate
        if self.edge_priority == "sjf":
            return task.model.t_edge            # SJF / Dedas ordering
        raise ValueError(self.edge_priority)

    # ------------------------------------------------------------------
    # Cloud admission (§5.1 / §5.3)
    # ------------------------------------------------------------------
    def offer_cloud(self, task: Task, now: float, t_cloud: float) -> CloudAccept:
        """Cloud scheduler admission check for ``task`` at time ``now``.

        ``t_cloud`` is the *current* expected cloud latency for the model
        (static, or DEMS-A-adapted).
        """
        if not self.use_cloud:
            return CloudAccept(False)
        m = task.model
        feasible = now + t_cloud <= task.abs_deadline
        if not feasible:
            return CloudAccept(False)
        if m.gamma_cloud <= 0 and not self.cloud_accepts_negative:
            if not self.stealing:
                return CloudAccept(False)
            # §5.3: park negative-utility tasks to be stolen; trigger is the
            # latest time the task could still start on the *edge*.
            trigger = task.abs_deadline - m.t_edge
            if trigger < now:
                return CloudAccept(False)
            return CloudAccept(True, trigger=trigger, steal_only=True)
        if self.stealing:
            trigger = max(now, task.abs_deadline - t_cloud - self.cloud_margin)
            return CloudAccept(True, trigger=trigger)
        return CloudAccept(True, trigger=now)   # FIFO, dispatch immediately

    # ------------------------------------------------------------------
    # Migration scoring (§5.2, Eqn 3)
    # ------------------------------------------------------------------
    @staticmethod
    def migration_decision(new: Task, victims: list[Task], now: float,
                           t_cloud_of) -> bool:
        """True → insert ``new`` on the edge and migrate ``victims`` to the
        cloud; False → redirect ``new`` itself to the cloud.

        A victim's score uses Eqn 3 with its *current* cloud feasibility.
        """
        def score(t: Task) -> float:
            feas = now + t_cloud_of(t.model) <= t.abs_deadline
            return migration_score(t.model, feas)

        s_new = score(new)
        s_victims = sum(score(v) for v in victims)
        return s_victims < s_new


@dataclasses.dataclass
class AdaptiveEstimator:
    """DEMS-A sliding-window cloud-latency estimator for one model (§5.4).

    Keeps a circular buffer of the last ``w`` observed cloud durations.
    When their average exceeds the current estimate by ``eps`` the estimate
    is raised to the average.  If the inflated estimate causes tasks to be
    skipped for longer than the cooling period ``t_cp``, reset to the
    static default and re-probe.
    """

    static: float
    w: int = 10
    eps: float = 10.0
    t_cp: float = 10_000.0
    current: float = dataclasses.field(default=0.0)
    _buf: list[float] = dataclasses.field(default_factory=list)
    _idx: int = 0
    _cooling_start: Optional[float] = None

    def __post_init__(self) -> None:
        if self.current == 0.0:
            self.current = self.static

    def observe(self, duration: float) -> None:
        if len(self._buf) < self.w:
            self._buf.append(duration)
        else:
            self._buf[self._idx] = duration
            self._idx = (self._idx + 1) % self.w
        avg = sum(self._buf) / len(self._buf)
        if avg - self.current > self.eps:
            self.current = avg

    def on_sent(self) -> None:
        self._cooling_start = None

    def on_skip(self, now: float) -> None:
        """A task was skipped because ``current`` predicts a deadline miss."""
        if self.current <= self.static:
            return
        if self._cooling_start is None:
            self._cooling_start = now
        elif now - self._cooling_start >= self.t_cp:
            self.current = self.static          # point-of-no-return reset
            self._cooling_start = None


_POLICIES = {
    "EDF":     dict(use_cloud=False, edge_feasibility_check=False),
    "HPF":     dict(use_cloud=False, edge_feasibility_check=False,
                    edge_priority="hpf"),
    "CLD":     dict(use_edge=False),
    "EDF-E+C": dict(),
    "SJF-E+C": dict(edge_priority="sjf", cloud_accepts_negative=True),
    "SOTA1":   dict(sota1=True),
    "SOTA2":   dict(edge_priority="sjf", sota2=True),
    "DEM":     dict(migration=True),
    "DEMS":    dict(migration=True, stealing=True),
    "DEMS-A":  dict(migration=True, stealing=True, adaptive=True),
    "GEMS":    dict(migration=True, stealing=True, gems=True),
    "GEMS-A":  dict(migration=True, stealing=True, gems=True, adaptive=True),
    # Beyond-paper (EXPERIMENTS.md §Perf-scheduler): Alg. 1's rate check
    # α̂ < α is *absorbing* at α=1.0 — once a window has one failure it can
    # never recover, yet GEMS keeps flooding the cloud for the rest of the
    # window, congesting other models.  GEMS-B reschedules only while the
    # window is still winnable (remaining arrivals could lift α̂ to α).
    "GEMS-B":  dict(migration=True, stealing=True, gems=True,
                    gems_budget=True),
}

ALL_POLICIES = tuple(_POLICIES)
BASELINES = ("EDF", "HPF", "CLD", "EDF-E+C", "SJF-E+C", "SOTA1", "SOTA2")


def make_policy(name: str, **overrides) -> Policy:
    if name not in _POLICIES:
        raise ValueError(f"unknown policy {name!r}; choose from {ALL_POLICIES}")
    kw = dict(_POLICIES[name])
    kw.update(overrides)
    return Policy(name=name, **kw)
