"""The paper's scheduling decisions as vectorized JAX kernels.

The Python policies in :mod:`repro.core.schedulers` make O(queue-length)
decisions per task.  Here each decision is a fixed-shape masked ``jnp``
computation over array-encoded queues, so an entire *fleet* of edges can be
stepped with ``vmap`` and sharded with ``pjit`` (see
:mod:`repro.sim.fleet_jax`).  This is the TPU-native rethink of the paper's
control plane: the per-VIP scheduler becomes one SPMD program over the
city-scale deployment the paper targets in §8.6.

Queues are structure-of-arrays with a validity mask:

* edge queue:  ``valid, key, seq, t_edge, deadline, abs_dl, model`` —
  ``key`` is the policy priority (EDF: absolute deadline; HPF: negated
  utility-per-edge-second; SJF: execution time — see
  :func:`edge_priority_key`), ``seq`` breaks ties by insertion order
  (stable, like the list-based oracle), ``deadline`` is the *scheduling*
  deadline (SOTA1 may extend it by its 10 % buffer) and ``abs_dl`` the
  absolute one that decides success (they differ only under SOTA1).
* cloud queue: ``valid, trigger, t_edge, deadline, steal_only, rank``
  (cloud deadlines are always absolute — the oracle's ``abs_deadline``).

Every function is pure, shape-stable and differentiable-free; all are
property-tested against the discrete-event oracle in
``tests/test_jax_sched.py``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import sched_ops

NEG = -1e30
POS = 1e30


class EdgeQueue(NamedTuple):
    """Array-encoded edge priority queue (capacity = arrays' length)."""

    valid: jax.Array     # bool[Q]
    key: jax.Array       # f32[Q]  policy priority (see edge_priority_key)
    seq: jax.Array       # i32[Q]  insertion counter (stable tie-break)
    t_edge: jax.Array    # f32[Q]  expected edge latency t_i
    deadline: jax.Array  # f32[Q]  scheduling deadline (abs, + SOTA1 ext)
    abs_dl: jax.Array    # f32[Q]  absolute deadline t'_j + δ_i (success)
    model: jax.Array     # i32[Q]


class CloudQueue(NamedTuple):
    """Array-encoded trigger-time cloud queue (§5.3)."""

    valid: jax.Array       # bool[Qc]
    trigger: jax.Array     # f32[Qc]
    t_edge: jax.Array      # f32[Qc] expected *edge* latency (for stealing)
    deadline: jax.Array    # f32[Qc] absolute deadline
    steal_only: jax.Array  # bool[Qc] negative-cloud-utility parkees
    rank: jax.Array        # f32[Qc] (γ^E−γ^C)/t_i steal rank


def empty_edge_queue(capacity: int) -> EdgeQueue:
    z = jnp.zeros(capacity)
    return EdgeQueue(valid=jnp.zeros(capacity, bool), key=z, seq=jnp.zeros(
        capacity, jnp.int32), t_edge=z, deadline=z, abs_dl=z,
        model=jnp.zeros(capacity, jnp.int32))


def empty_cloud_queue(capacity: int) -> CloudQueue:
    z = jnp.zeros(capacity)
    return CloudQueue(valid=jnp.zeros(capacity, bool), trigger=z, t_edge=z,
                      deadline=z, steal_only=jnp.zeros(capacity, bool),
                      rank=z)


# ---------------------------------------------------------------------------
# ordering helpers
# ---------------------------------------------------------------------------

def _ahead_matrix(q: EdgeQueue) -> jax.Array:
    """``ahead[i, j]`` — valid task j sits ahead of task i in the queue.

    Priority order is (key, seq) lexicographic, matching the stable
    insertion of the list-based oracle.
    """
    ki, kj = q.key[:, None], q.key[None, :]
    si, sj = q.seq[:, None], q.seq[None, :]
    earlier = (kj < ki) | ((kj == ki) & (sj < si))
    return earlier & q.valid[None, :]


def ahead_of_new(q: EdgeQueue, new_key: jax.Array) -> jax.Array:
    """Mask of queued tasks ahead of a to-be-inserted task.

    New tasks are inserted *after* equal keys (stable), so everything with
    ``key <= new_key`` is ahead.
    """
    return q.valid & (q.key <= new_key)


def projected_completions(q: EdgeQueue, now: jax.Array,
                          busy_rem: jax.Array) -> jax.Array:
    """Projected completion time of every queued task (§5.2)."""
    ahead = _ahead_matrix(q)
    wait = (ahead * q.t_edge[None, :]).sum(-1)
    return now + busy_rem + wait + q.t_edge


# ---------------------------------------------------------------------------
# §5.1 / §8.2 — edge-queue priority keys
# ---------------------------------------------------------------------------

# runtime codes for PolicyParams.edge_prio (oracle Policy.edge_priority)
PRIO_EDF = 0   # "edf": absolute scheduling deadline t'_j + δ_i (§5.1)
PRIO_HPF = 1   # "hpf": highest utility-per-edge-second first (§8.2)
PRIO_SJF = 2   # "sjf": shortest job first (SJF-E+C / Dedas ordering)


def edge_priority_key(prio, sched_deadline, t_edge_eff,
                      gamma_e) -> jax.Array:
    """The oracle's ``Policy.edge_key`` as a runtime-selected scalar.

    Lower key = higher priority, ties broken by insertion ``seq``.
    ``t_edge_eff`` is the *effective* edge latency (speed factor folded
    in), matching the oracle, whose per-edge model tables fold the factor
    before ``hpf_rank``/SJF read ``t_edge``.
    """
    hpf = -gamma_e / t_edge_eff          # −γ^E/t_i: greedy utility rate
    return jnp.where(prio == PRIO_HPF, hpf,
                     jnp.where(prio == PRIO_SJF, t_edge_eff,
                               sched_deadline))


# ---------------------------------------------------------------------------
# §5.1 — EDF insertion feasibility
# ---------------------------------------------------------------------------

def insert_feasible(q: EdgeQueue, now, busy_rem, new_key, new_t_edge,
                    new_deadline) -> jax.Array:
    """Sum of execution times ahead + own ≤ deadline (paper §5.1)."""
    wait = jnp.where(ahead_of_new(q, new_key), q.t_edge, 0.0).sum()
    return now + busy_rem + wait + new_t_edge <= new_deadline


# ---------------------------------------------------------------------------
# §8.2 — SOTA2 (Dedas) average-completion-time comparison
# ---------------------------------------------------------------------------

def act_improves(q: EdgeQueue, now, busy_rem, new_key,
                 new_t_edge) -> jax.Array:
    """Dedas tie-break: does inserting keep the mean completion time down?

    Mirrors the oracle's ``_route_sota2`` ACT comparison for the
    exactly-one-violation case: the mean projected completion time over
    all queued tasks *with* the insert (tasks behind the new key shift by
    ``new_t_edge``; the new task completes after everything ahead of it)
    must not exceed the mean *without* it.  An empty queue compares
    against +inf, so the insert always "improves".
    """
    proj = projected_completions(q, now, busy_rem)
    ahead = ahead_of_new(q, new_key)
    behind = q.valid & ~ahead
    n = q.valid.sum()
    act_before = jnp.where(n > 0, jnp.where(q.valid, proj, 0.0).sum()
                           / jnp.maximum(n, 1), POS)
    new_proj = (now + busy_rem + jnp.where(ahead, q.t_edge, 0.0).sum()
                + new_t_edge)
    after_sum = (jnp.where(q.valid, proj, 0.0).sum()
                 + jnp.where(behind, new_t_edge, 0.0).sum() + new_proj)
    act_after = after_sum / (n + 1)
    return act_after <= act_before


# ---------------------------------------------------------------------------
# §5.2 — migration: victims and Eqn-3 scoring
# ---------------------------------------------------------------------------

def victim_mask(q: EdgeQueue, now, busy_rem, new_key,
                new_t_edge) -> jax.Array:
    """Tasks *newly* pushed past their deadline by inserting the new task."""
    proj = projected_completions(q, now, busy_rem)
    behind = q.valid & (q.key > new_key)
    return behind & (proj <= q.deadline) & (q.deadline < proj + new_t_edge)


def eqn3_scores(model_ids, now, deadlines, gamma_e, gamma_c,
                t_cloud_cur) -> jax.Array:
    """Vectorized Eqn 3: S = γ^E−γ^C if cloud-feasible ∧ γ^C>0 else γ^E."""
    ge = gamma_e[model_ids]
    gc = gamma_c[model_ids]
    feasible = now + t_cloud_cur[model_ids] <= deadlines
    return jnp.where(feasible & (gc > 0), ge - gc, ge)


def migration_decision(q: EdgeQueue, victims: jax.Array, now,
                       new_model, new_deadline, gamma_e, gamma_c,
                       t_cloud_cur) -> jax.Array:
    """True → insert new task, migrate victims; False → redirect new (§5.2)."""
    s_victims = jnp.where(
        victims, eqn3_scores(q.model, now, q.deadline, gamma_e, gamma_c,
                             t_cloud_cur), 0.0).sum()
    s_new = eqn3_scores(jnp.asarray(new_model)[None], now,
                        jnp.asarray(new_deadline)[None],
                        gamma_e, gamma_c, t_cloud_cur)[0]
    return s_victims < s_new


# ---------------------------------------------------------------------------
# §5.3 — work stealing
# ---------------------------------------------------------------------------

def max_front_delay(q: EdgeQueue, now, busy_rem) -> jax.Array:
    """Largest execution time insertable at the queue head without pushing
    any queued task past its deadline; +inf when the queue is empty."""
    proj = projected_completions(q, now, busy_rem)
    margins = jnp.where(q.valid, q.deadline - proj, POS)
    return margins.min()


def head_slack(q: EdgeQueue, now) -> jax.Array:
    """σ of the head task: (t'_j+δ_i) − (now + t_i); +inf if queue empty.

    Note the paper computes slack for the *head*, i.e. the task that would
    execute now, so busy_rem is zero by construction.
    """
    ahead = _ahead_matrix(q)
    is_head = q.valid & (ahead.sum(-1) == 0)
    slack = jnp.where(is_head, q.deadline - (now + q.t_edge), POS)
    return slack.min()


def steal_select(cq: CloudQueue, q: EdgeQueue, now, busy_rem,
                 min_edge_t) -> jax.Array:
    """Index of the cloud-queue task to steal, or −1 (§5.3).

    Eligibility: fits in the front-insertion margin, still edge-feasible.
    Preference: steal-only (negative cloud utility) tasks first, then by
    descending rank (γ^E−γ^C)/t_i.
    """
    any_queued = q.valid.any()
    slack = head_slack(q, now)
    delay_cap = jnp.where(any_queued, max_front_delay(q, now, busy_rem), POS)
    gate = jnp.where(any_queued, slack > min_edge_t, True)
    eligible = (cq.valid
                & (cq.t_edge <= delay_cap)
                & (now + cq.t_edge <= cq.deadline)
                & gate)
    # lexicographic (steal_only desc, rank desc) via a scalar score
    score = jnp.where(cq.steal_only, 1e12, 0.0) + cq.rank
    idx, _ = sched_ops.masked_argmax(score, eligible)
    return idx


# ---------------------------------------------------------------------------
# cross-edge peer offload (fleet-scope work stealing, beyond-paper)
# ---------------------------------------------------------------------------

def queue_load(q: EdgeQueue, busy_rem) -> jax.Array:
    """Total pending edge work: banked execution time + queued t_edge."""
    return jnp.maximum(busy_rem, 0.0) + jnp.where(q.valid, q.t_edge, 0.0).sum()


def queue_slacks(q: EdgeQueue, now, busy_rem) -> jax.Array:
    """Per-slot slack (deadline − projected completion); +inf for empties."""
    proj = projected_completions(q, now, busy_rem)
    return jnp.where(q.valid, q.deadline - proj, POS)


def export_select(q: EdgeQueue, now, busy_rem, dst_load,
                  slack_thresh) -> jax.Array:
    """Index of the task an overloaded edge should export, or −1.

    Candidates are queued tasks whose local slack is below
    ``slack_thresh`` (projected to miss, or nearly so) that would still be
    feasible appended behind the destination edge's current load.  The
    worst-slack candidate is exported first — the fleet-scope mirror of
    §5.3's "steal the task that needs rescue most".
    """
    slacks = queue_slacks(q, now, busy_rem)
    feasible_dst = now + dst_load + q.t_edge <= q.deadline
    cand = q.valid & feasible_dst & (slacks < slack_thresh)
    idx, _ = sched_ops.masked_argmin(slacks, cand)
    return idx


# ---------------------------------------------------------------------------
# §6 — GEMS window rescheduler (Alg. 1 lines 9–14)
# ---------------------------------------------------------------------------

def gems_reschedule_mask(q: EdgeQueue, now, lag_model, t_cloud_cur,
                         gamma_c) -> jax.Array:
    """Pending edge tasks of the lagging model to push to the cloud."""
    positive = gamma_c[lag_model] > 0
    feasible = now + t_cloud_cur[lag_model] <= q.deadline
    return q.valid & (q.model == lag_model) & feasible & positive


def window_update(lam, lam_hat, success) -> tuple[jax.Array, jax.Array,
                                                  jax.Array]:
    """Alg. 1 lines 3–7: increment counts, return the incremental rate."""
    lam = lam + 1
    lam_hat = lam_hat + success.astype(lam_hat.dtype)
    return lam, lam_hat, lam_hat / lam


def gems_winnable(lam, lam_hat, prev_lam, alpha, now, win_end,
                  window) -> jax.Array:
    """GEMS-B (beyond-paper): can α̂ still reach α this window?

    Vectorized mirror of the oracle's ``_WindowState.winnable``: the
    remaining arrivals are forecast from the *previous* window's count
    (``prev_lam``, prorated by the fraction of the window left); if even
    an all-success tail cannot lift the rate to α the window is
    mathematically lost and Alg. 1's rescheduling flood is pointless.
    """
    frac_left = jnp.clip((win_end - now) / window, 0.0, None)
    remaining = jnp.maximum(prev_lam, lam) * frac_left
    return lam_hat + remaining >= alpha * (lam + remaining) - 1e-9


# ---------------------------------------------------------------------------
# §5.4 — DEMS-A adaptation
# ---------------------------------------------------------------------------

class AdaptState(NamedTuple):
    buf: jax.Array            # f32[M, w] circular buffers
    count: jax.Array          # i32[M] observations so far (≤ w)
    idx: jax.Array            # i32[M] next write slot
    current: jax.Array        # f32[M] current estimates t̂
    cooling_start: jax.Array  # f32[M]; −1 = not cooling


def adapt_init(static: jax.Array, w: int) -> AdaptState:
    m = static.shape[0]
    return AdaptState(buf=jnp.zeros((m, w)), count=jnp.zeros(m, jnp.int32),
                      idx=jnp.zeros(m, jnp.int32), current=static,
                      cooling_start=-jnp.ones(m))


def adapt_observe(st: AdaptState, model, obs, eps: float) -> AdaptState:
    """Mirror of ``AdaptiveEstimator.observe``: append until the buffer
    fills (write position = count), then overwrite circularly."""
    w = st.buf.shape[1]
    filling = st.count[model] < w
    write = jnp.where(filling, st.count[model], st.idx[model])
    buf = st.buf.at[model, write].set(obs)
    count = st.count.at[model].set(jnp.minimum(st.count[model] + 1, w))
    idx = st.idx.at[model].set(
        jnp.where(filling, st.idx[model], (st.idx[model] + 1) % w))
    n = count[model]
    avg = buf[model].sum() / n
    cur = st.current.at[model].set(
        jnp.where(avg - st.current[model] > eps, avg, st.current[model]))
    return AdaptState(buf, count, idx, cur, st.cooling_start)


def adapt_on_sent(st: AdaptState, model) -> AdaptState:
    return st._replace(cooling_start=st.cooling_start.at[model].set(-1.0))


def adapt_select(pred, a: AdaptState, b: AdaptState) -> AdaptState:
    """Elementwise ``where`` over whole estimator states (masked updates).

    The fleet tick loop computes a candidate post-event state for every
    queue slot and keeps it only where the event actually fired.
    """
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def adapt_on_skip(st: AdaptState, model, now, static, t_cp) -> AdaptState:
    inflated = st.current[model] > static[model]
    cs = st.cooling_start[model]
    expired = (cs >= 0) & (now - cs >= t_cp)
    new_cur = jnp.where(inflated & expired, static[model], st.current[model])
    new_cs = jnp.where(~inflated, cs,
                       jnp.where(expired, -1.0, jnp.where(cs < 0, now, cs)))
    return AdaptState(st.buf, st.count, st.idx,
                      st.current.at[model].set(new_cur),
                      st.cooling_start.at[model].set(new_cs))


def adapt_feed_batch(st: AdaptState, model_ids, sent, obs, obs_val, skip,
                     now, static, eps, t_cp, *, with_obs: bool = True,
                     max_obs: int | None = None) -> AdaptState:
    """One batched estimator update for a whole tick's worth of events.

    Replaces the per-queue-slot ``fori_loop`` of
    ``on_sent``/``observe``/``on_skip`` calls with masked array updates:
    per model, every ``sent`` cooling reset applies, then all ``obs``
    observations land in slot order (their values must be equal within
    one call — true in the fleet tick, where a model's actual duration is
    a function of (model, tick) only), then at most one ``skip``
    (same-instant repeated skips are idempotent).

    **Event-ordering caveat (sends-then-skips).**  A model that both
    dispatches *and* skips in the same tick diverges from the sequential
    slot loop: the loop interleaves events in queue-slot order (a skip in
    slot 2 lands *before* a send in slot 5), whereas this batch applies
    all sends first, then the skips.  The divergence is confined to the
    cooling timer: a slot-ordered ``skip → send`` pair starts cooling and
    immediately clears it (net no-op), while the batch's ``send → skip``
    leaves the model cooling from ``now``.  Both orders agree again at
    the next dispatch (any send clears the timer), so the visible effect
    is bounded to at most one cooling window ``t_cp`` *starting* a few
    slots early — it can only make the §5.4 point-of-no-return reset
    fire sooner, never later, and only for models mixing sends and skips
    within one ``dt``.  No registry scenario exercises this (a tick's
    dispatch gate is feasibility-monotone per model: same-model entries
    share one t̂, so they skip together or send together; mixes need a
    deadline straddle within a single tick).  If a future scenario makes
    the interleave matter, thread each event's queue-slot index into this
    call and fold it into the per-model segment reductions (order the
    replay tensors by slot instead of assuming sends-first) — the same
    batched-per-tick simplification :mod:`repro.sim.fleet_jax` documents
    for DEMS-A.

    With all masks False the state is returned bit-identical, so callers
    gate adaptivity by AND-ing a runtime policy flag into the masks.
    ``with_obs=False`` skips building the observation tensors for
    skip-only call sites (rejected cloud offers).  ``max_obs`` promises
    that no model observes more than that many times in this call (e.g.
    the finite pool depth — one tick cannot dispatch more tasks than it
    has free slots); it bounds the ``[M, j, w]`` replay tensors and the
    ratchet, the hottest per-tick allocation.
    """
    m, w = st.buf.shape
    k = model_ids.shape[0]
    cnt = jax.ops.segment_sum(obs.astype(jnp.int32), model_ids,
                              num_segments=m)                     # i32[M]
    cs = jnp.where(
        jax.ops.segment_sum(sent.astype(jnp.int32), model_ids,
                            num_segments=m) > 0,
        -1.0, st.cooling_start)
    cur, buf, count, idx = st.current, st.buf, st.count, st.idx
    if with_obs:
        jmax = k if max_obs is None else min(k, max_obs)
        v = jax.ops.segment_max(jnp.where(obs, obs_val, NEG), model_ids,
                                num_segments=m)                   # f32[M]
        j = jnp.arange(jmax)[None, :]                             # [1,J]
        fill = jnp.clip(w - count, 0, None)[:, None]              # [M,1]
        # the j-th observation of model m writes slot: fill positions
        # count..w-1 first, then wrap circularly from idx (the exact
        # write path of adapt_observe, iterated)
        pos = jnp.where(j < fill, count[:, None] + j,
                        (idx[:, None] + j - fill) % w)            # [M,J]
        active = j < cnt[:, None]
        onehot = active[:, :, None] & (
            pos[:, :, None] == jnp.arange(w)[None, None, :])      # [M,J,w]
        written_upto = jnp.cumsum(onehot, axis=1) > 0
        buf = jnp.where(written_upto[:, -1, :], v[:, None], buf)
        # the current-estimate ratchet is path-dependent (an average only
        # sticks when it clears cur+eps), so replay the per-observation
        # averages — but as J tiny [M]-wide steps, not K full-state scans
        sums = st.buf.sum(-1)[:, None] + jnp.where(
            written_upto, v[:, None, None] - st.buf[:, None, :],
            0.0).sum(-1)                                          # [M,J]
        nobs = jnp.minimum(count[:, None] + 1 + jnp.arange(jmax)[None, :],
                           w)
        avgs = sums / nobs

        def ratchet(jj, c):
            a = avgs[:, jj]
            return jnp.where((jj < cnt) & (a - c > eps), a, c)

        cur = jax.lax.fori_loop(0, jmax, ratchet, cur)
        count = jnp.minimum(st.count + cnt, w)
        idx = (st.idx + (cnt - jnp.clip(w - st.count, 0, cnt))) % w
    any_skip = jax.ops.segment_sum(skip.astype(jnp.int32), model_ids,
                                   num_segments=m) > 0
    inflated = cur > static
    expired = (cs >= 0) & (now - cs >= t_cp)
    new_cur = jnp.where(any_skip & inflated & expired, static, cur)
    new_cs = jnp.where(
        any_skip,
        jnp.where(~inflated, cs,
                  jnp.where(expired, -1.0, jnp.where(cs < 0, now, cs))),
        cs)
    return AdaptState(buf, count, idx, new_cur, new_cs)


# ---------------------------------------------------------------------------
# queue mutation helpers (used by the fleet simulator)
# ---------------------------------------------------------------------------

def edge_push(q: EdgeQueue, key, seq, t_edge, deadline, model,
              enable=True, abs_dl=None) -> tuple[EdgeQueue, jax.Array]:
    """Insert into the first free slot; returns (queue, ok).

    ``abs_dl`` is the absolute deadline deciding success; it defaults to
    ``deadline`` (they differ only under SOTA1's scheduling extension).
    """
    abs_dl = deadline if abs_dl is None else abs_dl
    free = ~q.valid
    slot = jnp.argmax(free)
    ok = free.any() & enable
    def set_at(arr, v):
        return jnp.where(ok, arr.at[slot].set(v), arr)
    return EdgeQueue(
        valid=set_at(q.valid, True), key=set_at(q.key, key),
        seq=set_at(q.seq, seq), t_edge=set_at(q.t_edge, t_edge),
        deadline=set_at(q.deadline, deadline),
        abs_dl=set_at(q.abs_dl, abs_dl), model=set_at(q.model, model),
    ), ok


def edge_pop_head(q: EdgeQueue) -> tuple[EdgeQueue, jax.Array, jax.Array]:
    """Remove and return the head (index, found) by (key, seq) order."""
    ahead = _ahead_matrix(q)
    is_head = q.valid & (ahead.sum(-1) == 0)
    idx = jnp.argmax(is_head)
    found = is_head.any()
    return q._replace(valid=jnp.where(found, q.valid.at[idx].set(False),
                                      q.valid)), idx, found


def edge_remove(q: EdgeQueue, mask: jax.Array) -> EdgeQueue:
    return q._replace(valid=q.valid & ~mask)


def cloud_push(cq: CloudQueue, trigger, t_edge, deadline, steal_only,
               rank, enable=True) -> tuple[CloudQueue, jax.Array]:
    free = ~cq.valid
    slot = jnp.argmax(free)
    ok = free.any() & enable
    def set_at(arr, v):
        return jnp.where(ok, arr.at[slot].set(v), arr)
    return CloudQueue(
        valid=set_at(cq.valid, True), trigger=set_at(cq.trigger, trigger),
        t_edge=set_at(cq.t_edge, t_edge),
        deadline=set_at(cq.deadline, deadline),
        steal_only=set_at(cq.steal_only, steal_only),
        rank=set_at(cq.rank, rank)), ok


def cloud_remove(cq: CloudQueue, idx) -> CloudQueue:
    return cq._replace(valid=cq.valid.at[idx].set(False))
