"""Task and utility model for edge+cloud DNN inference scheduling (paper §4).

A *task* ``τ_i^j`` is the execution of DNN model ``μ_i`` on video segment
``v_j`` created at the base station at time ``t'_j``.  Each model carries a
benefit ``β_i``, a deadline duration ``δ_i``, expected execution latencies on
the edge (``t_i``) and cloud (``t̂_i``) and per-task monetary costs ``K_i``
(edge) / ``K̂_i`` (cloud).

QoS utility (Eqn 1, using the Table-1 identity γ^E = β−K, γ^C = β−K̂):

    success on edge   →  β − K          late on edge  → −K
    success on cloud  →  β − K̂          late on cloud → −K̂
    dropped           →  0

QoE utility (Eqn 2): a per-model tumbling window of duration ``ω_i`` accrues
``β̄_i`` iff at least an ``α_i`` fraction of the tasks *finishing* inside the
window completed within their deadline.

All times are in **milliseconds** unless stated otherwise.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Outcome(enum.Enum):
    """Terminal state of a task (paper Eqn 1 cases)."""

    EDGE_SUCCESS = "edge_success"
    EDGE_MISS = "edge_miss"
    CLOUD_SUCCESS = "cloud_success"
    CLOUD_MISS = "cloud_miss"
    DROPPED = "dropped"


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Static profile of a registered DNN model μ_i (paper Table 1 / 2).

    ``t`` / ``t_hat`` are the *expected* (95th/99th-pct benchmarked)
    latencies used by the scheduler; actual durations are sampled by the
    simulator / measured by the serve engine.
    """

    name: str
    beta: float          # QoS benefit β_i
    deadline: float      # deadline duration δ_i  [ms]
    t_edge: float        # expected edge latency t_i  [ms]
    t_cloud: float       # expected cloud latency t̂_i  [ms]
    cost_edge: float     # per-task edge cost K_i
    cost_cloud: float    # per-task cloud cost K̂_i
    qoe_beta: float = 0.0    # QoE window benefit β̄_i (Eqn 2)
    qoe_alpha: float = 0.0   # required completion rate α_i in a window
    qoe_window: float = 20_000.0  # window duration ω_i  [ms]

    @property
    def gamma_edge(self) -> float:
        """Expected utility of an on-time edge execution, γ^E = β − K."""
        return self.beta - self.cost_edge

    @property
    def gamma_cloud(self) -> float:
        """Expected utility of an on-time cloud execution, γ^C = β − K̂."""
        return self.beta - self.cost_cloud

    @property
    def hpf_rank(self) -> float:
        """Utility-per-edge-time rank used by the HPF baseline (§8.2)."""
        return self.gamma_edge / self.t_edge

    def steal_rank(self) -> float:
        """Work-stealing rank (§5.3): (γ^E − γ^C) / t_i."""
        return (self.gamma_edge - self.gamma_cloud) / self.t_edge


@dataclasses.dataclass
class Task:
    """One inference task τ_i^j."""

    uid: int
    model: ModelProfile
    created: float               # t'_j  [ms] — segment creation time
    drone: int = 0
    # -- scheduling state ----------------------------------------------
    deadline_ext: float = 0.0    # SOTA1 deadline buffer (scheduling only)
    steal_only: bool = False     # negative-cloud-utility task parked on the
                                 # cloud queue purely to be stolen (§5.3)
    gems_rescheduled: bool = False
    stolen: bool = False
    migrated: bool = False
    # -- result ---------------------------------------------------------
    outcome: Optional[Outcome] = None
    finished: Optional[float] = None  # completion timestamp [ms]

    @property
    def abs_deadline(self) -> float:
        """Absolute deadline t'_j + δ_i (also the EDF priority, §5.1)."""
        return self.created + self.model.deadline

    @property
    def sched_deadline(self) -> float:
        """Deadline used for *scheduling* decisions (SOTA1 may extend it)."""
        return self.abs_deadline + self.deadline_ext

    def utility(self) -> float:
        """Realized QoS utility γ_i^j (Eqn 1)."""
        m = self.model
        if self.outcome is Outcome.EDGE_SUCCESS:
            return m.gamma_edge
        if self.outcome is Outcome.EDGE_MISS:
            return -m.cost_edge
        if self.outcome is Outcome.CLOUD_SUCCESS:
            return m.gamma_cloud
        if self.outcome is Outcome.CLOUD_MISS:
            return -m.cost_cloud
        return 0.0

    @property
    def success(self) -> bool:
        return self.outcome in (Outcome.EDGE_SUCCESS, Outcome.CLOUD_SUCCESS)


def migration_score(m: ModelProfile, cloud_feasible: bool) -> float:
    """DEM migration score S_i^j (Eqn 3).

    S = γ^E − γ^C   if the task would finish on time on the cloud and
                    γ^C > 0 (cheap to hand over — small score);
    S = γ^E         otherwise (handing it over forfeits its whole value).
    """
    if cloud_feasible and m.gamma_cloud > 0:
        return m.gamma_edge - m.gamma_cloud
    return m.gamma_edge


# ---------------------------------------------------------------------------
# Paper workload profiles.
# ---------------------------------------------------------------------------

# Table 1 — Jetson Nano / AWS Lambda profiles for the six Ocularone DNNs.
#                      name   β     δ      t     t̂     K   K̂
TABLE1 = {
    "HV":  ModelProfile("HV", 125,  650, 174, 398, 1,  25),
    "DEV": ModelProfile("DEV", 100, 750, 172, 429, 1,  26),
    # NOTE: Table 1 lists K̂=15 for MD but its γ^C column says 50 = 75−25.
    # The γ columns drive every heuristic, so we take K̂=25 (15 is a typo).
    "MD":  ModelProfile("MD",  75,  850, 142, 589, 1,  25),
    "BP":  ModelProfile("BP",  40,  900, 244, 542, 2,  43),   # γ^C = −3 !
    "CD":  ModelProfile("CD", 175, 1000, 563, 878, 4, 152),
    "DEO": ModelProfile("DEO", 250, 950, 739, 832, 6, 210),
}

PASSIVE = ("HV", "DEV", "MD", "BP")
ACTIVE = ("HV", "DEV", "MD", "BP", "CD", "DEO")


def table2(workload: str, alpha: float) -> list[ModelProfile]:
    """Table 2 — GEMS QoE workloads WL1 / WL2 on the alternate edge/cloud.

    QoS β and costs K, K̂ are retained from Table 1; β̄, δ, t, t̂ come from
    Table 2; ω = 20 s for all models (§6.1).
    """
    t1 = TABLE1

    def mk(name: str, qoe_beta: float, dl: float, te: float, tc: float) -> ModelProfile:
        base = t1[name]
        return dataclasses.replace(
            base, deadline=dl, t_edge=te, t_cloud=tc,
            qoe_beta=qoe_beta, qoe_alpha=alpha, qoe_window=20_000.0)

    if workload == "WL1":
        return [mk("HV", 360, 400, 100, 200), mk("DEV", 420, 600, 300, 400),
                mk("MD", 480, 1000, 200, 300), mk("CD", 600, 800, 650, 750)]
    if workload == "WL2":
        return [mk("HV", 360, 400, 100, 200), mk("DEV", 420, 600, 300, 400),
                mk("MD", 480, 800, 200, 300), mk("CD", 600, 1000, 750, 950)]
    raise ValueError(f"unknown GEMS workload {workload!r}")


# §8.8 field-validation profiles on Jetson Orin Nano (HV@30FPS, DEV/BP@10FPS).
ORIN = {
    "HV":  dataclasses.replace(TABLE1["HV"], t_edge=49, cost_edge=1),
    "DEV": dataclasses.replace(TABLE1["DEV"], t_edge=50, cost_edge=1),
    "BP":  dataclasses.replace(TABLE1["BP"], t_edge=72, cost_edge=1),
}
