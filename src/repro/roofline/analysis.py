"""Roofline derivation from compiled dry-run artifacts (no real hardware).

Terms per (arch × shape × mesh), all **per device** (XLA cost/memory
analyses are post-SPMD-partitioning, i.e. already per device):

    compute_s    = HLO_FLOPs / PEAK_FLOPS_BF16
    memory_s     = HLO_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW

``cost_analysis`` counts a ``while`` (scan) body exactly once, so FLOPs /
bytes come from the **delta method**: compile the step with layers fully
*unrolled* at two small layer counts L₁ < L₂, then extrapolate
``base + L·per_layer`` to the full depth.  Collective bytes are parsed out
of the optimized HLO (result-shape bytes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute), with while-body
collectives scaled by the known trip count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_OP_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*"
                      r"(?:->\s*[^{]*)?\{\s*$")


@dataclasses.dataclass
class Collective:
    computation: str
    kind: str
    dtype: str
    shape: tuple[int, ...]
    bytes: int


def parse_collectives(hlo_text: str) -> list[Collective]:
    """Extract every collective op with its result size, tagged by the HLO
    computation it lives in (entry vs while-body etc.)."""
    out: list[Collective] = []
    comp = "entry"
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m and "{" in line:
            comp = m.group(1)
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            if "-done(" in line:
                continue          # matching -start already counted
            shape = tuple(int(x) for x in dims.split(",")) if dims else ()
            nbytes = _DTYPE_BYTES.get(dtype, 4)
            for d in shape:
                nbytes *= d
            out.append(Collective(comp, kind, dtype, shape, nbytes))
    return out


def collective_bytes(hlo_text: str, body_trip_count: int = 1) -> dict:
    """Total collective bytes; while-body collectives × trip count.

    Any collective inside a non-entry computation that looks like a loop
    body (name contains 'while' or 'body') is scaled.
    """
    per_kind: dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    total = 0.0
    for c in parse_collectives(hlo_text):
        mult = body_trip_count if ("body" in c.computation
                                   or "while" in c.computation) else 1
        per_kind[c.kind] += c.bytes * mult
        total += c.bytes * mult
    per_kind["total"] = total
    return per_kind


@dataclasses.dataclass
class RooflineTerms:
    flops: float               # per device
    hbm_bytes: float           # per device
    coll_bytes: float          # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str

    @classmethod
    def build(cls, flops: float, hbm_bytes: float,
              coll_bytes: float) -> "RooflineTerms":
        c = flops / PEAK_FLOPS_BF16
        m = hbm_bytes / HBM_BW
        l = coll_bytes / ICI_BW
        names = {"compute": c, "memory": m, "collective": l}
        return cls(flops, hbm_bytes, coll_bytes, c, m, l,
                   bottleneck=max(names, key=names.get))


def extrapolate(v1: float, v2: float, l1: int, l2: int,
                l_full: float) -> float:
    """base + L·per_layer through (l1, v1), (l2, v2) evaluated at l_full."""
    per = (v2 - v1) / (l2 - l1)
    base = v1 - per * l1
    return max(base + per * l_full, 0.0)


def model_flops(cfg, shape_name: str, seq: int, batch: int) -> float:
    """Analytic MODEL_FLOPS: 6·N·D for training, 2·N_active·D for serving
    (decode: D = batch tokens per step)."""
    n = cfg.active_param_count()
    if shape_name.startswith("train"):
        return 6.0 * n * seq * batch
    if shape_name.startswith("prefill"):
        return 2.0 * n * seq * batch
    return 2.0 * n * batch          # decode: one token per sequence
