"""Real-time serving engine: the paper's scheduler over live JAX inference.

This is the §8.8 field-validation analogue: instead of simulated durations,
tasks are actual jitted forward passes of (reduced) zoo models.  The
runtime mirrors the paper's architecture (§3.3):

* an **edge executor** — one synchronous worker thread (Jetson-class GPUs
  execute kernels serially; same discipline here) pulling from an EDF
  priority queue;
* a **cloud executor** — a thread pool whose calls run the same model but
  pay a shaped network delay (sim/network.py), i.e. FaaS semantics;
* the **task scheduler** applying a core.schedulers Policy verbatim
  (E+C / DEM / DEMS / DEMS-A / GEMS) — admission, migration scoring, work
  stealing via trigger times, adaptation, window rescheduling.

Timestamps are wall-clock milliseconds; results aggregate into the same
per-model stats as the simulator, so emulation and live runs are directly
comparable.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import threading
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedulers import AdaptiveEstimator, Policy
from repro.core.task import ModelProfile, Outcome, Task
from repro.sim.engine import ModelStats, Results
from repro.sim.network import CloudLatencyModel


def _now_ms() -> float:
    return time.monotonic() * 1e3


@dataclasses.dataclass
class ServableModel:
    """A registered DNN: profile + a zero-arg jitted invocation."""

    profile: ModelProfile
    run: Callable[[], object]          # blocking inference call

    @classmethod
    def from_arch(cls, profile: ModelProfile, cfg, batch: int = 1,
                  seq: int = 32, seed: int = 0) -> "ServableModel":
        """Wrap a reduced zoo model's forward pass as the task payload."""
        from repro.models.model import Model
        model = Model(cfg)
        rng = jax.random.PRNGKey(seed)
        params = model.init(rng)
        tokens = jax.random.randint(rng, (batch, seq), 0, cfg.vocab)
        b = {"tokens": tokens}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros((batch, cfg.n_frames, cfg.d_model))
        if cfg.family == "vlm":
            b["patches"] = jnp.zeros((batch, cfg.n_image_tokens,
                                      cfg.d_model))
        fwd = jax.jit(lambda p, bb: model.forward(p, bb)[0])
        fwd(params, b)[0].block_until_ready()     # warm the cache

        def run():
            return fwd(params, b).block_until_ready()

        return cls(profile=profile, run=run)


class ServeEngine:
    """Edge+cloud inference service under a paper policy."""

    def __init__(self, policy: Policy, models: dict[str, ServableModel], *,
                 cloud_concurrency: int = 4,
                 cloud_model: Optional[CloudLatencyModel] = None,
                 seed: int = 0):
        self.policy = policy
        self.models = models
        self.cloud_net = cloud_model or CloudLatencyModel()
        self.rng = np.random.default_rng(seed)
        self.adaptive = {n: AdaptiveEstimator(static=m.profile.t_cloud)
                         for n, m in models.items()}
        self.stats = {n: ModelStats() for n in models}
        # flight-recorder samples for metrics_snapshot(): bounded ring
        # buffers of per-task completion latency and deadline slack (ms)
        self._lat_samples = collections.deque(maxlen=4096)
        self._slack_samples = collections.deque(maxlen=4096)
        self._lock = threading.RLock()
        self._edge_q: list[tuple[float, int, Task]] = []
        self._cloud_q: list[tuple[float, int, Task]] = []
        self._seq = 0
        self._uid = 0
        self._stop = threading.Event()
        self._edge_kick = threading.Condition(self._lock)
        self._t0 = _now_ms()
        self.min_edge_t = min(m.profile.t_edge for m in models.values())
        self._edge_thread = threading.Thread(target=self._edge_loop,
                                             daemon=True)
        self._cloud_threads = [
            threading.Thread(target=self._cloud_loop, daemon=True)
            for _ in range(cloud_concurrency)]

    # ------------------------------------------------------------------
    def start(self):
        self._edge_thread.start()
        for t in self._cloud_threads:
            t.start()

    def stop(self):
        self._stop.set()
        with self._edge_kick:
            self._edge_kick.notify_all()

    def now(self) -> float:
        return _now_ms() - self._t0

    def _t_cloud(self, name: str) -> float:
        if self.policy.adaptive:
            return self.adaptive[name].current
        return self.models[name].profile.t_cloud

    # ------------------------------------------------------------------
    # submission (task scheduler thread, §3.3/§5)
    # ------------------------------------------------------------------
    def submit(self, model_name: str, created: Optional[float] = None
               ) -> Task:
        m = self.models[model_name].profile
        with self._lock:
            self._uid += 1
            task = Task(uid=self._uid, model=m,
                        created=self.now() if created is None else created)
            self.stats[model_name].generated += 1
            self._route(task)
        return task

    def _route(self, task: Task) -> None:
        now = self.now()
        pos, feasible = self._edge_feasible(task, now)
        if feasible:
            if self.policy.migration:
                victims = self._victims(pos, task, now)
                if victims and not self.policy.migration_decision(
                        task, victims, now, lambda m: self._t_cloud(m.name)):
                    self._offer_cloud(task) or self._drop(task)
                    return
                for v in victims:
                    self._edge_remove(v)
                    v.migrated = True
                    self.stats[v.model.name].migrated += 1
                    self._offer_cloud(v) or self._drop(v)
            self._edge_insert(task)
        else:
            self._offer_cloud(task) or self._drop(task)

    def _edge_items(self) -> list[Task]:
        return [t for _, _, t in sorted(self._edge_q)]

    def _edge_feasible(self, task: Task, now: float):
        key = self.policy.edge_key(task)
        items = self._edge_items()
        ahead = [t for t in items if self.policy.edge_key(t) <= key]
        wait = sum(t.model.t_edge for t in ahead)
        pos = len(ahead)
        return pos, now + wait + task.model.t_edge <= task.sched_deadline

    def _victims(self, pos: int, task: Task, now: float) -> list[Task]:
        items = self._edge_items()
        cur = now
        proj = []
        for t in items:
            cur += t.model.t_edge
            proj.append(cur)
        out = []
        for i in range(pos, len(items)):
            t = items[i]
            if proj[i] <= t.sched_deadline < proj[i] + task.model.t_edge:
                out.append(t)
        return out

    def _edge_insert(self, task: Task) -> None:
        self._seq += 1
        heapq.heappush(self._edge_q,
                       (self.policy.edge_key(task), self._seq, task))
        with self._edge_kick:
            self._edge_kick.notify()

    def _edge_remove(self, task: Task) -> None:
        self._edge_q = [(k, s, t) for k, s, t in self._edge_q
                        if t.uid != task.uid]
        heapq.heapify(self._edge_q)

    def _offer_cloud(self, task: Task) -> bool:
        acc = self.policy.offer_cloud(task, self.now(),
                                      self._t_cloud(task.model.name))
        if not acc.accept:
            if self.policy.adaptive:
                self.adaptive[task.model.name].on_skip(self.now())
            return False
        task.steal_only = acc.steal_only
        self._seq += 1
        heapq.heappush(self._cloud_q, (acc.trigger, self._seq, task))
        return True

    def _drop(self, task: Task) -> bool:
        task.outcome = Outcome.DROPPED
        task.finished = self.now()
        self.stats[task.model.name].dropped += 1
        self._after_completion(task, success=False)
        return True

    # ------------------------------------------------------------------
    # executors
    # ------------------------------------------------------------------
    def _edge_loop(self) -> None:
        while not self._stop.is_set():
            task = None
            with self._lock:
                now = self.now()
                while self._edge_q:
                    head = self._edge_q[0][2]
                    if now + head.model.t_edge > head.sched_deadline:
                        heapq.heappop(self._edge_q)
                        self._drop(head)
                    else:
                        break
                if self.policy.stealing:
                    task = self._try_steal(now)
                if task is None and self._edge_q:
                    task = heapq.heappop(self._edge_q)[2]
            if task is None:
                with self._edge_kick:
                    self._edge_kick.wait(timeout=0.005)
                continue
            self.models[task.model.name].run()        # synchronous inference
            self._finish(task, "edge")

    def _try_steal(self, now: float) -> Optional[Task]:
        if self._edge_q:
            head = self._edge_q[0][2]
            slack = head.abs_deadline - (now + head.model.t_edge)
            if slack <= self.min_edge_t:
                return None
            items = self._edge_items()
            cur = now
            margins = []
            for t in items:
                cur += t.model.t_edge
                margins.append(t.sched_deadline - cur)
            max_delay = min(margins)
            if max_delay <= 0:
                return None
        else:
            max_delay = float("inf")
        best, best_key = None, None
        for trig, s, c in self._cloud_q:
            if c.model.t_edge <= max_delay and \
                    now + c.model.t_edge <= c.abs_deadline:
                key = (not c.steal_only, -c.model.steal_rank())
                if best is None or key < best_key:
                    best, best_key = (trig, s, c), key
        if best is None:
            return None
        self._cloud_q.remove(best)
        heapq.heapify(self._cloud_q)
        best[2].stolen = True
        self.stats[best[2].model.name].stolen += 1
        return best[2]

    def _cloud_loop(self) -> None:
        while not self._stop.is_set():
            task = None
            with self._lock:
                now = self.now()
                if self._cloud_q and self._cloud_q[0][0] <= now:
                    task = heapq.heappop(self._cloud_q)[2]
                    if task.steal_only:
                        self._drop(task)
                        task = None
                    else:
                        est = self._t_cloud(task.model.name)
                        if now + est > task.abs_deadline:
                            self._drop(task)
                            if self.policy.adaptive:
                                self.adaptive[task.model.name].on_skip(now)
                            task = None
                        elif self.policy.adaptive:
                            self.adaptive[task.model.name].on_sent()
            if task is None:
                time.sleep(0.002)
                continue
            t_start = self.now()
            delay = self.cloud_net.shaped_delta(t_start) + \
                max(0.0, float(self.rng.normal(30.0, 10.0)))  # RTT jitter
            # shaped_delta is signed (above-nominal bandwidth speeds the
            # transfer up), so the sum can go below zero — sleep() can't
            time.sleep(max(delay, 0.0) / 1e3)
            self.models[task.model.name].run()
            if self.policy.adaptive:
                self.adaptive[task.model.name].observe(
                    self.now() - t_start)
            self._finish(task, "cloud")

    # ------------------------------------------------------------------
    def _finish(self, task: Task, where: str) -> None:
        with self._lock:
            task.finished = self.now()
            ok = task.finished <= task.abs_deadline
            st = self.stats[task.model.name]
            if where == "edge":
                task.outcome = Outcome.EDGE_SUCCESS if ok else \
                    Outcome.EDGE_MISS
                st.edge_success += ok
                st.edge_miss += (not ok)
                st.edge_utility += task.utility()
            else:
                task.outcome = Outcome.CLOUD_SUCCESS if ok else \
                    Outcome.CLOUD_MISS
                st.cloud_success += ok
                st.cloud_miss += (not ok)
                st.cloud_utility += task.utility()
            st.qos_utility += task.utility()
            if ok:
                self._lat_samples.append(task.finished - task.created)
                self._slack_samples.append(task.abs_deadline - task.finished)
            self._after_completion(task, success=ok)

    def _after_completion(self, task: Task, success: bool) -> None:
        """GEMS window accounting (Alg. 1) on each completion/drop."""
        if not self.policy.gems or task.model.qoe_alpha <= 0:
            return
        # window state piggybacks on ModelStats via simple counters
        st = self.stats[task.model.name]
        if not hasattr(st, "_win"):
            st._win = [task.model.qoe_window, 0, 0]   # end, lam, lam_hat
        win = st._win
        now = self.now()
        while now > win[0]:
            if win[1] > 0:
                st.windows_total += 1
                if win[2] / win[1] >= task.model.qoe_alpha:
                    st.windows_met += 1
                    st.qoe_utility += task.model.qoe_beta
            win[0] += task.model.qoe_window
            win[1] = win[2] = 0
        win[1] += 1
        win[2] += success
        if win[2] / win[1] < task.model.qoe_alpha and \
                task.model.gamma_cloud > 0:
            est = self._t_cloud(task.model.name)
            moved = [(k, s, t) for k, s, t in self._edge_q
                     if t.model.name == task.model.name
                     and now + est <= t.abs_deadline]
            for item in moved:
                self._edge_q.remove(item)
                t = item[2]
                t.gems_rescheduled = True
                st.gems_rescheduled += 1
                self._seq += 1
                heapq.heappush(self._cloud_q, (now, self._seq, t))
            if moved:
                heapq.heapify(self._edge_q)

    # ------------------------------------------------------------------
    def metrics_snapshot(self) -> dict:
        """Live observability endpoint: the flight recorder's serve twin.

        One lock-protected read returning the same scoreboard
        :func:`repro.obs.metrics.tail_metrics` computes for the
        simulator — per-model outcome counts and QoE success
        frequencies, queue depths, and p50/p95/p99 completion-latency /
        deadline-slack percentiles over a bounded window of recent
        completions.  Cheap enough to poll from a control plane.
        """
        with self._lock:
            per_model = {}
            hit = miss = drop = 0
            for n, st in self.stats.items():
                ok = st.edge_success + st.cloud_success
                bad = st.edge_miss + st.cloud_miss
                settled = ok + bad + st.dropped
                per_model[n] = dict(
                    generated=st.generated, hit=ok, miss=bad,
                    dropped=st.dropped, stolen=st.stolen,
                    migrated=st.migrated,
                    qoe_frequency=ok / settled if settled else None)
                hit, miss, drop = hit + ok, miss + bad, drop + st.dropped
            lat = np.asarray(self._lat_samples, dtype=np.float64)
            slack = np.asarray(self._slack_samples, dtype=np.float64)

            def pcts(a):
                if a.size == 0:
                    return {f"p{q:g}": None for q in (50, 95, 99)}
                return {f"p{q:g}": float(np.percentile(a, q))
                        for q in (50, 95, 99)}

            settled = max(hit + miss + drop, 1)
            return dict(
                now_ms=self.now(), policy=self.policy.name,
                hit=hit, miss=miss, dropped=drop,
                hit_rate=hit / settled,
                edge_queue_depth=len(self._edge_q),
                cloud_queue_depth=len(self._cloud_q),
                latency_ms=pcts(lat), slack_ms=pcts(slack),
                window=dict(latency_samples=int(lat.size),
                            slack_samples=int(slack.size)),
                per_model=per_model,
                qos_utility=sum(st.qos_utility
                                for st in self.stats.values()),
                qoe_utility=sum(st.qoe_utility
                                for st in self.stats.values()))

    def results(self, duration_ms: float) -> Results:
        busy = sum((st.edge_success + st.edge_miss) *
                   self.models[n].profile.t_edge
                   for n, st in self.stats.items())
        return Results(policy=self.policy.name, duration=duration_ms,
                       per_model=self.stats, edge_busy=busy)


def run_stream(engine: ServeEngine, fps: dict[str, float],
               duration_ms: float) -> Results:
    """Drive a frame stream: submit each model at its FPS for the duration."""
    engine.start()
    t_end = duration_ms
    next_at = {n: 0.0 for n in fps}
    while engine.now() < t_end:
        now = engine.now()
        for n, f in fps.items():
            if now >= next_at[n]:
                engine.submit(n)
                next_at[n] += 1000.0 / f
        time.sleep(0.002)
    # drain
    time.sleep(0.3)
    engine.stop()
    return engine.results(duration_ms)
