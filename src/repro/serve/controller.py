"""Online fleet control plane over the compiled tick program.

:class:`FleetController` is the streaming twin of the replay entry
points: telemetry (task arrivals, per-edge bandwidth and WAN-latency
readings, cloud availability) is ingested incrementally into a
:class:`repro.scenarios.compile.SignalWindowBuilder`, popped as
dt-aligned :class:`~repro.sim.fleet_jax.FleetSignals` windows, and
advanced through the jitted
:meth:`repro.sim.fleet_jax.FleetProgram.step_chunk` — one bounded-latency
device call per window, no host round-trips inside.  Because the tick
scan composes exactly, a controller fed a replay scenario's signals
window-by-window finishes in the **bitwise-identical** final
:class:`~repro.sim.fleet_jax.EdgeState` as one :func:`~repro.sim.
fleet_jax.run_fleet` call (``tests/test_controller.py`` and the
``scenarios/runner.py`` equivalence hook pin this).

The controller also carries the serve layer's operational duties:

* per-tick decision records derived from the flight recorder's
  :class:`~repro.obs.trace.TickCounters` stream (routing, migration,
  steals, drops by cause) via :meth:`FleetController.poll`;
* a :meth:`~FleetController.metrics_snapshot` scoreboard mirroring
  :meth:`repro.serve.engine.ServeEngine.metrics_snapshot` — outcome
  totals, queue gauges, latency/slack tails from trace histograms, and
  the controller's own step-latency percentiles;
* crash restart: :meth:`~FleetController.checkpoint` /
  :meth:`~FleetController.restore` round-trip the full ``EdgeState``
  (plus the tick cursor) through :mod:`repro.train.checkpoint`, so a
  restarted controller resumes mid-mission and — given the same
  post-checkpoint telemetry — finishes with the same summary as an
  uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.task import ModelProfile
from repro.obs.trace import TraceSpec
from repro.scenarios.compile import SignalWindowBuilder
from repro.sim.fleet_jax import (CLOUD_SLOTS, EdgeState, FleetProgram,
                                 FleetSignals, Profiles, _resolve_policy)
from repro.train import checkpoint as ckpt

# fleet-summed per-tick decision counters surfaced in decision records
_DECISION_FIELDS = (
    "arrivals", "admit_edge", "admit_cloud", "migrated", "cloud_dispatch",
    "pool_blocked", "gems_moved", "edge_exec", "peer_out", "peer_in",
    "drop_infeasible", "drop_unstolen", "drop_qfull", "drop_crash",
    "drop_timeout")


class FleetController:
    """Stateful online scheduler for one edge fleet.

    Ingestion (:meth:`submit`, :meth:`observe_bandwidth`,
    :meth:`observe_theta`, :meth:`observe_load`, :meth:`observe_cloud`,
    :meth:`observe_edge_up`, :meth:`observe_link_up`) only buffers —
    nothing runs until :meth:`poll` finds at least ``window_ticks``
    complete ticks behind ``now_ms``, keeping each device call a
    fixed-shape window (one compile per window length).  :meth:`close`
    flushes the ragged remainder.

    The ingest queue is **bounded** at ``max_pending_ticks`` of buffered
    telemetry.  A submission landing past the bound is handled by
    ``shed_policy``: ``"reject"`` refuses it (returns ``-1``, counted in
    ``shed_tasks``) while ``"degrade"`` force-steps the oldest pending
    window to make room — trading telemetry completeness for admission,
    counted in ``degrade_windows``.  Either way the controller never
    deadlocks and never grows unbounded under an arrival flood.

    Passing ``task_id`` to :meth:`submit` makes ingestion **idempotent**
    over the last ``dedupe_window`` distinct ids: redelivered ids are
    dropped (counted in ``duplicate_events``), so an at-least-once
    telemetry bus replaying events after :meth:`restore` cannot
    double-schedule work.  The dedupe ring rides in the checkpoint.
    """

    def __init__(self, models: Sequence[ModelProfile], policy, *,
                 n_edges: int, dt: float = 25.0, window_ticks: int = 8,
                 cloud_slots: int = CLOUD_SLOTS, edge_frac: float = 0.62,
                 cloud_frac: float = 0.80,
                 trace: Optional[TraceSpec] = None,
                 checkpoint_path: Optional[str] = None,
                 checkpoint_every: int = 4, order_seed: int = 0,
                 decision_log: int = 4096, latency_log: int = 512,
                 max_pending_ticks: int = 4096,
                 shed_policy: str = "reject",
                 dedupe_window: int = 4096,
                 cloud_give_up_ms: Optional[float] = None):
        self.models = list(models)
        self.policy_name = policy if isinstance(policy, str) else "custom"
        self._pol = _resolve_policy(policy)
        if cloud_give_up_ms is not None:
            self._pol = dataclasses.replace(
                self._pol, cloud_give_up_ms=float(cloud_give_up_ms))
        self._prof = Profiles.build(self.models)
        self._pp = self._pol.params()
        self.trace = TraceSpec(counters=True) if trace is None else trace
        self.n_edges, self.dt = int(n_edges), float(dt)
        self.window_ticks = int(window_ticks)
        self.cloud_slots = cloud_slots
        self.order_seed = order_seed
        self.prog = FleetProgram.for_policy(
            self._pol, trace=self.trace, dt=dt, edge_frac=edge_frac,
            cloud_frac=cloud_frac)
        self.state: EdgeState = self.prog.init(
            self._prof, self._pol, n_edges, cloud_slots)
        self._model_idx = {m.name: i for i, m in enumerate(self.models)}
        self.builder = self._new_builder(0)
        self.checkpoint_path = checkpoint_path
        self.checkpoint_every = int(checkpoint_every)
        self.windows_run = 0
        self.checkpoints_written = 0
        self.decisions: deque[dict] = deque(maxlen=decision_log)
        self._step_ms: deque[float] = deque(maxlen=latency_log)
        self._ingest_lag_ms: deque[float] = deque(maxlen=latency_log)
        self._submit_walltime: dict[int, float] = {}
        # running trace aggregates (histograms sum exactly across windows)
        self._slack_hist: Optional[np.ndarray] = None
        self._latency_hist: Optional[np.ndarray] = None
        self._last_gauges = dict(eq_depth=0, cq_depth=0, slots_busy=0)
        # -- robustness: bounded ingest + idempotent replay ---------------
        if shed_policy not in ("reject", "degrade"):
            raise ValueError(
                f"shed_policy must be 'reject' or 'degrade', "
                f"got {shed_policy!r}")
        if max_pending_ticks < self.window_ticks:
            raise ValueError(
                f"max_pending_ticks ({max_pending_ticks}) must cover at "
                f"least one window ({self.window_ticks} ticks)")
        self.max_pending_ticks = int(max_pending_ticks)
        self.shed_policy = shed_policy
        self.shed_tasks = 0
        self.degrade_windows = 0
        self.late_events = 0
        self.duplicate_events = 0
        # fixed-shape dedupe ring (checkpointable): last N task ids seen
        self._dedupe_ids = np.full(int(dedupe_window), -1, np.int64)
        self._dedupe_pos = 0
        self._dedupe_set: set[int] = set()

    def _new_builder(self, start_tick: int) -> SignalWindowBuilder:
        return SignalWindowBuilder(
            self.n_edges, len(self.models), dt=self.dt,
            start_tick=start_tick, order_seed=self.order_seed)

    # -- telemetry ingestion ----------------------------------------------
    def _midx(self, model: Union[int, str]) -> int:
        return self._model_idx[model] if isinstance(model, str) else int(model)

    def _remember(self, task_id: int) -> None:
        evicted = int(self._dedupe_ids[self._dedupe_pos
                                       % len(self._dedupe_ids)])
        if evicted >= 0:
            self._dedupe_set.discard(evicted)
        self._dedupe_ids[self._dedupe_pos % len(self._dedupe_ids)] = task_id
        self._dedupe_set.add(int(task_id))
        self._dedupe_pos += 1

    def submit(self, t_ms: float, edge: int, model: Union[int, str],
               task_id: Optional[int] = None) -> int:
        """A task arrival at ``edge``; returns its scheduled tick.

        ``task_id`` (a non-negative int) makes the call idempotent:
        redeliveries of an id still in the dedupe ring return ``-1``
        without scheduling anything.  A ``-1`` return also signals a
        shed arrival under the ``"reject"`` backpressure policy; late
        arrivals (behind the emit cursor) clamp forward and are counted
        in ``late_events``.
        """
        if task_id is not None:
            if int(task_id) < 0:
                raise ValueError(f"task_id must be >= 0, got {task_id}")
            if int(task_id) in self._dedupe_set:
                self.duplicate_events += 1
                return -1
        if int(t_ms / self.dt) < self.tick:
            self.late_events += 1
        while int(t_ms / self.dt) >= self.tick + self.max_pending_ticks:
            if self.shed_policy == "reject":
                self.shed_tasks += 1
                return -1
            # "degrade": force-step the oldest pending window to make
            # room — admission wins over telemetry completeness
            self.degrade_windows += 1
            self._advance(self.window_ticks)
        if task_id is not None:
            self._remember(int(task_id))
        tick = self.builder.add_arrival(t_ms, edge, self._midx(model))
        # first submission per tick stamps the wall clock for lag stats
        self._submit_walltime.setdefault(tick, time.monotonic())
        return tick

    def observe_bandwidth(self, t_ms: float, mbps: float,
                          edge: Optional[int] = None) -> None:
        self.builder.set_bandwidth(t_ms, mbps, edge)

    def observe_theta(self, t_ms: float, theta_ms: float,
                      edge: Optional[int] = None) -> None:
        self.builder.set_theta(t_ms, theta_ms, edge)

    def observe_load(self, t_ms: float, mult: float,
                     edge: Optional[int] = None) -> None:
        self.builder.set_load(t_ms, mult, edge)

    def observe_cloud(self, t_ms: float, up: bool) -> None:
        self.builder.set_cloud_up(t_ms, up)

    def observe_edge_up(self, t_ms: float, up: bool,
                        edge: Optional[int] = None) -> None:
        """Edge liveness telemetry — ``False`` crashes the edge (queue
        flush, no admission) from ``t_ms`` until set ``True`` again."""
        self.builder.set_edge_up(t_ms, up, edge)

    def observe_link_up(self, t_ms: float, up: bool,
                        edge: Optional[int] = None) -> None:
        """Edge↔cloud link telemetry — ``False`` partitions the edge
        (cloud dispatches park, GEMS migration halts)."""
        self.builder.set_link_up(t_ms, up, edge)

    # -- stepping ----------------------------------------------------------
    @property
    def tick(self) -> int:
        """The next tick to be scheduled (the window builder's cursor)."""
        return self.builder.cursor

    @property
    def now_ms(self) -> float:
        """Simulation time already scheduled."""
        return self.tick * self.dt

    def poll(self, now_ms: float) -> list[dict]:
        """Advance over every complete ``window_ticks`` window ≤ ``now_ms``.

        Returns the new per-tick decision records (also appended to
        :attr:`decisions`).  Ticks at or after ``now_ms`` stay buffered —
        they may still receive telemetry.
        """
        out: list[dict] = []
        while self.tick + self.window_ticks <= int(now_ms / self.dt):
            out.extend(self._advance(self.window_ticks))
        return out

    def close(self) -> list[dict]:
        """Flush buffered telemetry as one final (ragged) window."""
        n = self.builder.pending_ticks
        return self._advance(n) if n else []

    def step_signals(self, window: FleetSignals) -> list[dict]:
        """Advance over an externally compiled window (replay bridging).

        The streaming-equivalence path: feeding
        :func:`repro.scenarios.compile.compile_fleet` output window-by-
        window through this method reproduces :func:`~repro.sim.
        fleet_jax.run_fleet` bitwise.  The internal builder's cursor is
        kept in step so :meth:`metrics_snapshot` reports the right time.
        """
        n = int(np.shape(window.times)[0])
        self.builder = self._new_builder(self.tick + n)
        return self._run_window(window)

    def _advance(self, n_ticks: int) -> list[dict]:
        return self._run_window(self.builder.emit_window(n_ticks))

    def _run_window(self, window: FleetSignals) -> list[dict]:
        tick0 = self.tick - int(np.shape(window.times)[0])
        t0 = time.monotonic()
        self.state, res = self.prog.step_chunk(
            self._prof, self._pp, self.state, window)
        jax.block_until_ready(self.state)
        wall = time.monotonic()
        self._step_ms.append((wall - t0) * 1e3)
        records = self._record(tick0, res)
        for tk in list(self._submit_walltime):
            if tk < self.tick:
                self._ingest_lag_ms.append(
                    (wall - self._submit_walltime.pop(tk)) * 1e3)
        self.windows_run += 1
        if (self.checkpoint_path is not None and
                self.windows_run % self.checkpoint_every == 0):
            self.checkpoint()
        return records

    def _record(self, tick0: int, res) -> list[dict]:
        if res is None or res.counters is None:
            return []
        tr = jax.tree.map(np.asarray, res.counters)   # [T, E, …] leaves
        events = {f: getattr(tr, f).sum(axis=1) for f in _DECISION_FIELDS}
        hit, miss = tr.hit.sum(axis=(1, 2)), tr.miss.sum(axis=(1, 2))
        drop, stolen = tr.drop.sum(axis=(1, 2)), tr.stolen.sum(axis=(1, 2))
        records = []
        for i in range(tr.arrivals.shape[0]):
            rec = dict(tick=tick0 + i, time_ms=(tick0 + i) * self.dt,
                       hit=int(hit[i]), miss=int(miss[i]),
                       drop=int(drop[i]), stolen=int(stolen[i]))
            rec.update({f: int(v[i]) for f, v in events.items()})
            records.append(rec)
        self.decisions.extend(records)
        if tr.slack_hist is not None:
            h = tr.slack_hist.reshape(-1, tr.slack_hist.shape[-1]).sum(0)
            self._slack_hist = h if self._slack_hist is None \
                else self._slack_hist + h
            h = tr.latency_hist.reshape(-1, tr.latency_hist.shape[-1]).sum(0)
            self._latency_hist = h if self._latency_hist is None \
                else self._latency_hist + h
        self._last_gauges = dict(
            eq_depth=int(tr.eq_depth[-1].sum()),
            cq_depth=int(tr.cq_depth[-1].sum()),
            slots_busy=int(tr.slots_busy[-1].sum()))
        return records

    # -- observability -----------------------------------------------------
    def reset_latency_stats(self) -> None:
        """Drop step-latency / ingest-lag samples (e.g. after warmup, so
        benchmark percentiles exclude the one-off window compile)."""
        self._step_ms.clear()
        self._ingest_lag_ms.clear()

    @property
    def step_latencies_ms(self) -> list[float]:
        """Wall-clock per-window step latencies (recent, bounded)."""
        return list(self._step_ms)

    @property
    def ingest_lags_ms(self) -> list[float]:
        """Wall-clock first-submit→decision lags per stepped tick."""
        return list(self._ingest_lag_ms)

    def summary(self) -> dict:
        """Mission-so-far scalar metrics (the replay ``fleet_summary``)."""
        from repro.scenarios.runner import fleet_summary
        return fleet_summary(self.state)

    def metrics_snapshot(self) -> dict:
        """Live scoreboard — the :class:`~repro.serve.engine.ServeEngine`
        endpoint's compiled-controller twin, cheap enough to poll."""
        from repro.obs.metrics import hist_percentiles

        def pcts(a: Sequence[float]) -> dict:
            arr = np.asarray(a, dtype=np.float64)
            if arr.size == 0:
                return {f"p{q:g}": None for q in (50, 95, 99)}
            return {f"p{q:g}": float(np.percentile(arr, q))
                    for q in (50, 95, 99)}

        snap = dict(
            now_ms=self.now_ms, tick=self.tick, policy=self.policy_name,
            n_edges=self.n_edges, window_ticks=self.window_ticks,
            windows_run=self.windows_run,
            checkpoints_written=self.checkpoints_written,
            pending_ticks=self.builder.pending_ticks,
            max_pending_ticks=self.max_pending_ticks,
            shed_policy=self.shed_policy,
            shed_tasks=self.shed_tasks,
            degrade_windows=self.degrade_windows,
            late_events=self.late_events,
            duplicate_events=self.duplicate_events,
            step_latency_ms=pcts(self._step_ms),
            ingest_to_decision_ms=pcts(self._ingest_lag_ms),
            decisions_logged=len(self.decisions),
            **self.summary())
        snap.update(self._last_gauges)
        if self._latency_hist is not None:
            snap["latency_ms"] = hist_percentiles(self._latency_hist,
                                                  self.trace)
            snap["slack_ms"] = hist_percentiles(self._slack_hist, self.trace)
        return snap

    # -- crash restart -----------------------------------------------------
    def _ckpt_tree(self, state: EdgeState, tick: int) -> dict:
        # the dedupe ring is part of durable state: replayed task ids
        # must still be recognized after a crash restart (idempotent
        # at-least-once ingestion); both leaves are fixed-shape
        return {"state": state, "tick": np.int64(tick),
                "dedupe_ids": self._dedupe_ids.copy(),
                "dedupe_pos": np.int64(self._dedupe_pos)}

    def checkpoint(self, path: Optional[str] = None) -> str:
        """Persist scheduler state + tick cursor; returns the path stem."""
        path = path or self.checkpoint_path
        if path is None:
            raise ValueError("no checkpoint path configured")
        ckpt.save(path, self._ckpt_tree(self.state, self.tick))
        self.checkpoints_written += 1
        return path

    def restore(self, path: Optional[str] = None) -> int:
        """Resume from a checkpoint; returns the restored tick cursor.

        Telemetry buffered but not yet stepped when the checkpoint was
        written is *not* part of it — upstream must replay events since
        the checkpoint tick (the at-least-once ingestion contract,
        `docs/SERVING.md`).
        """
        path = path or self.checkpoint_path
        if path is None:
            raise ValueError("no checkpoint path configured")
        like = self._ckpt_tree(
            self.prog.init(self._prof, self._pol, self.n_edges,
                           self.cloud_slots), 0)
        data = ckpt.load(path, like)
        self.state = jax.tree.map(
            lambda a, b: np.asarray(a, dtype=np.asarray(b).dtype),
            data["state"], like["state"])
        tick = int(data["tick"])
        self.builder = self._new_builder(tick)
        self._submit_walltime.clear()
        self._dedupe_ids = np.asarray(data["dedupe_ids"],
                                      np.int64).copy()
        self._dedupe_pos = int(data["dedupe_pos"])
        self._dedupe_set = {int(i) for i in self._dedupe_ids if i >= 0}
        return tick


def drive_stream(ctl: FleetController, fps: dict, duration_ms: float, *,
                 poll_every_ms: Optional[float] = None,
                 stop: Optional[Callable[[], bool]] = None) -> dict:
    """Virtual-time frame-stream driver — the compiled-controller twin of
    :func:`repro.serve.engine.run_stream`.

    Submits each model at its frame rate (tasks round-robined over the
    fleet's edges), polls the controller on a fixed cadence so windows
    step as soon as their ticks complete, flushes the remainder, and
    returns the final :meth:`~FleetController.metrics_snapshot`.

    ``stop`` is checked once per poll cadence; returning ``True`` ends
    the stream early but still flushes buffered ticks and (when the
    controller has a checkpoint path) writes a final checkpoint — the
    graceful-shutdown hook ``launch/serve.py`` wires to SIGINT/SIGTERM.
    """
    poll_every = poll_every_ms or ctl.window_ticks * ctl.dt
    next_at = {n: 0.0 for n in fps}
    edge_rr = 0
    now = 0.0
    while now < duration_ms:
        if stop is not None and stop():
            break
        horizon = min(now + poll_every, duration_ms)
        for n, f in fps.items():
            while next_at[n] < horizon:
                ctl.submit(next_at[n], edge_rr % ctl.n_edges, n)
                edge_rr += 1
                next_at[n] += 1000.0 / f
        now = horizon
        ctl.poll(now)
    ctl.close()
    if ctl.checkpoint_path is not None:
        ctl.checkpoint()
    return ctl.metrics_snapshot()
