"""Scenario engine: declarative fleet missions compiled to both simulators.

See :mod:`repro.scenarios.spec` for the vocabulary,
:mod:`repro.scenarios.registry` for the named library, and
:mod:`repro.scenarios.runner` for one-call execution on the discrete-event
oracle or the JAX fleet simulator.
"""
from repro.faults import (Brownout, EdgeCrash, FaultSpec, Flood, Jamming,
                          Partition, TelemetryChaos)
from repro.scenarios.compile import (OracleInputs, SweepRun,
                                     compile_exec_jitter, compile_fleet,
                                     compile_fleet_batch, compile_oracle,
                                     compile_registry_batch,
                                     compile_registry_groups)
from repro.sim.fleet_jax import plan_buckets
from repro.scenarios.registry import SCENARIOS, get, names
from repro.scenarios.runner import (fleet_summary, fleet_summary_batch,
                                    merge_results, run_registry_sweep,
                                    run_scenario_fleet,
                                    run_scenario_fleet_batch,
                                    run_scenario_oracle)
from repro.scenarios.spec import (BandwidthTrace, Burst, CloudOutage,
                                  DroneSpec, DurationJitter, EdgeSite,
                                  ScenarioSpec, ThetaTrapezium)

__all__ = [
    "BandwidthTrace", "Brownout", "Burst", "CloudOutage", "DroneSpec",
    "DurationJitter", "EdgeCrash", "EdgeSite", "FaultSpec", "Flood",
    "Jamming", "OracleInputs", "Partition",
    "SCENARIOS", "ScenarioSpec", "SweepRun", "TelemetryChaos",
    "ThetaTrapezium",
    "compile_exec_jitter", "compile_fleet", "compile_fleet_batch",
    "compile_oracle", "compile_registry_batch", "compile_registry_groups",
    "fleet_summary",
    "fleet_summary_batch", "get", "merge_results", "names", "plan_buckets",
    "run_registry_sweep", "run_scenario_fleet", "run_scenario_fleet_batch",
    "run_scenario_oracle",
]
