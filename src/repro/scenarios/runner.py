"""Drive a compiled scenario through either simulator and merge results.

``run_scenario_oracle`` runs one discrete-event :class:`Simulator` per
edge site (each with its own θ trace, outage windows and speed-scaled
model table) and merges the per-edge :class:`Results`.
``run_scenario_fleet`` lowers the same spec to dense tick signals and runs
the vmapped/shardable JAX fleet simulator, optionally with cross-edge
peer offload (``FleetPolicy.cooperation`` / ``"<name>-COOP"``).
``run_scenario_fleet_batch`` sweeps one scenario over many seeds as a
single compiled program (one jit instead of R Python-loop jits).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.schedulers import make_policy
from repro.scenarios.compile import (compile_exec_jitter, compile_fleet,
                                     compile_fleet_batch, compile_oracle)
from repro.scenarios.spec import ScenarioSpec
from repro.sim.engine import FleetOracle, ModelStats, Results, Simulator
from repro.sim.network import (CloudLatencyModel, EdgeLatencyModel,
                               TableCloudLatencyModel,
                               TableEdgeLatencyModel)


def merge_results(results: list[Results]) -> Results:
    """Fleet-wide totals: per-model stats summed across edge sites."""
    per_model: dict[str, ModelStats] = {}
    for r in results:
        for name, st in r.per_model.items():
            agg = per_model.setdefault(name, ModelStats())
            for f in dataclasses.fields(ModelStats):
                setattr(agg, f.name,
                        getattr(agg, f.name) + getattr(st, f.name))
    # duration = total edge-time so edge_utilization reads as fleet average
    return Results(policy=results[0].policy if results else "?",
                   duration=sum(r.duration for r in results),
                   per_model=per_model,
                   edge_busy=sum(r.edge_busy for r in results))


@dataclasses.dataclass
class OracleScenarioRun:
    spec: ScenarioSpec
    per_edge: list[Results]
    merged: Results


def run_scenario_oracle(spec: ScenarioSpec, policy: str, *,
                        edge_model: EdgeLatencyModel | None = None,
                        cloud_concurrency: int | None = None,
                        cloud_model_overrides: dict | None = None,
                        cloud_give_up_ms: float = float("inf"),
                        dt: float = 25.0,
                        **policy_overrides) -> OracleScenarioRun:
    """One event-driven Simulator per edge site.

    ``cloud_concurrency`` defaults to ``spec.cloud_concurrency`` (each
    edge's share of the bounded FaaS pool); ``cloud_model_overrides``
    replaces :class:`CloudLatencyModel` fields (e.g. ``sigma=1e-6`` for
    deterministic fleet-agreement comparisons) while the compiled θ and
    bandwidth traces stay attached.

    With ``spec.jitter`` set, both latency models become table-backed
    (:class:`~repro.sim.network.TableEdgeLatencyModel` /
    :class:`~repro.sim.network.TableCloudLatencyModel`) over the *same*
    per-(tick, model) sample tables the fleet simulator consumes as its
    ``exec_jit`` lane — same-sample fleet-vs-oracle comparisons.

    With ``spec.faults`` set, the compiled chaos lowering rides along:
    flood arrivals are already merged into each edge's stream, θ/bw
    traces carry the jamming and brownout overlays, partitions surface
    as per-edge zero-cold outage windows and edge crashes as
    ``edge_down_windows``.  ``cloud_give_up_ms`` bounds how long a
    parked cloud dispatch waits before being abandoned — pass the same
    value as the fleet side's ``FleetPolicy.cloud_give_up_ms`` for
    agreement runs.

    A ``*-COOP`` policy runs the per-edge simulators through the
    :class:`~repro.sim.engine.FleetOracle` lockstep wrapper (base policy
    on each edge + cross-edge peer offload between ``dt`` slices,
    mirroring the fleet's exchange); silo policies keep the independent
    per-edge loop.
    """
    coop = policy.endswith("-COOP")
    base_policy = policy[:-5] if coop else policy
    compiled = compile_oracle(spec)
    jit_tables = None
    if spec.jitter is not None:
        jit_tables = compile_exec_jitter(spec, dt)
        if edge_model is None:
            edge_model = TableEdgeLatencyModel(
                table=jit_tables[0], names=spec.model_names, dt=dt)
    sims: list[Simulator] = []
    for e, arrivals in enumerate(compiled.edge_arrivals):
        shaping = dict(latency_at=compiled.theta_fns[e],
                       bandwidth_at=compiled.bw_fns[e])
        if jit_tables is not None:
            cloud_model = TableCloudLatencyModel(
                table=jit_tables[1], names=spec.model_names, dt=dt,
                **shaping, **(cloud_model_overrides or {}))
        else:
            cloud_model = CloudLatencyModel(
                **shaping, **(cloud_model_overrides or {}))
        sims.append(Simulator(
            make_policy(base_policy, **policy_overrides), arrivals,
            spec.duration_ms,
            cloud_concurrency=spec.cloud_concurrency
            if cloud_concurrency is None else cloud_concurrency,
            edge_model=edge_model, cloud_model=cloud_model,
            cloud_outages=compiled.edge_outages[e]
            if compiled.edge_outages is not None else compiled.outages,
            edge_down_windows=compiled.crashes[e]
            if compiled.crashes is not None else (),
            cloud_give_up_ms=cloud_give_up_ms,
            seed=spec.seed + e))
    if coop:
        from repro.sim.fleet_jax import FleetPolicy
        fp = FleetPolicy.from_name(policy)
        per_edge = FleetOracle(
            sims, spec.duration_ms, dt=dt, slack_ms=fp.coop_slack_ms,
            max_transfers=fp.coop_max_transfers).run()
    else:
        per_edge = [sim.run() for sim in sims]
    return OracleScenarioRun(spec=spec, per_edge=per_edge,
                             merged=merge_results(per_edge))


def run_scenario_fleet(spec: ScenarioSpec, policy, *, dt: float = 25.0,
                       edge_frac: float = 0.62, cloud_frac: float = 0.80,
                       mesh=None, record_trace: bool = False, trace=None):
    """The scenario through the JAX fleet simulator (stacked EdgeState).

    The spec's ``cloud_concurrency`` becomes each edge's finite
    ``cloud_slots`` pool, matching the oracle path slot for slot.
    ``trace`` (a :class:`repro.obs.trace.TraceSpec`; ``record_trace`` is
    the deprecated ``TraceSpec(t_hat=True)`` alias) returns a
    ``FleetResult`` carrying the requested flight-recorder streams —
    per-tick adapted-t̂ (``[T, E, M]``, Fig. 12-style adaptation
    dynamics) and/or decision counters.
    """
    from repro.sim.fleet_jax import run_fleet

    signals = compile_fleet(spec, dt)
    return run_fleet(spec.models, policy, signals, dt=dt,
                     edge_frac=edge_frac, cloud_frac=cloud_frac,
                     cloud_slots=spec.cloud_concurrency, mesh=mesh,
                     record_trace=record_trace, trace=trace)


def stream_scenario_fleet(spec: ScenarioSpec, policy, *, dt: float = 25.0,
                          window_ticks: int = 16, edge_frac: float = 0.62,
                          cloud_frac: float = 0.80, trace=None):
    """The scenario through the *online* control plane, window-by-window.

    Compiles the same dense signals as :func:`run_scenario_fleet`, then
    feeds them through a :class:`repro.serve.controller.FleetController`
    in ``window_ticks`` chunks via its replay bridge
    (:meth:`~repro.serve.controller.FleetController.step_signals`).
    Returns the controller; its ``state`` is the streamed final
    :class:`~repro.sim.fleet_jax.EdgeState`.
    """
    from repro.obs.trace import TraceSpec
    from repro.serve.controller import FleetController
    from repro.sim.fleet_jax import slice_signals

    sig = compile_fleet(spec, dt)
    ctl = FleetController(
        spec.models, policy, n_edges=spec.n_edges, dt=dt,
        window_ticks=window_ticks, cloud_slots=spec.cloud_concurrency,
        edge_frac=edge_frac, cloud_frac=cloud_frac,
        trace=TraceSpec() if trace is None else trace)
    n_ticks = int(sig.times.shape[0])
    for lo in range(0, n_ticks, window_ticks):
        ctl.step_signals(slice_signals(sig, lo, min(lo + window_ticks,
                                                    n_ticks)))
    return ctl


def assert_streaming_equivalence(spec: ScenarioSpec, policy, *,
                                 dt: float = 25.0, window_ticks: int = 16
                                 ) -> dict[str, float]:
    """Replay-vs-streaming bitwise check (the equivalence test hook).

    Runs the scenario both ways — one :func:`run_scenario_fleet` replay
    call and a :class:`~repro.serve.controller.FleetController` stepping
    the identical signals window-by-window — and raises
    ``AssertionError`` naming the diverging ``EdgeState`` fields unless
    every leaf is bit-for-bit equal.  Returns the (shared) summary.
    """
    from repro.sim.fleet_jax import EdgeState

    ref = run_scenario_fleet(spec, policy, dt=dt)
    ctl = stream_scenario_fleet(spec, policy, dt=dt,
                                window_ticks=window_ticks)
    bad = [name for name, a, b in zip(EdgeState._fields, ref, ctl.state)
           if not all(np.array_equal(np.asarray(x), np.asarray(y))
                      for x, y in zip(jax.tree.leaves(a),
                                      jax.tree.leaves(b)))]
    if bad:
        raise AssertionError(
            f"streaming EdgeState diverged from replay in fields {bad} "
            f"({spec.name!r}, policy {policy!r}, "
            f"window_ticks={window_ticks})")
    return fleet_summary(ctl.state)


def run_scenario_fleet_batch(spec: ScenarioSpec, policy,
                             seeds: tuple[int, ...], *, dt: float = 25.0,
                             edge_frac: float = 0.62,
                             cloud_frac: float = 0.80, mesh=None,
                             record_trace: bool = False, trace=None):
    """One scenario × many seeds as one compiled fleet program.

    Returns a stacked final EdgeState with leading ``[R, E]`` axes;
    use :func:`fleet_summary_batch` for per-seed metrics.  ``trace`` /
    ``record_trace`` switch to a ``FleetResult`` with replica-leading
    streams (``t_hat`` shaped ``[R, T, E, M]``).
    """
    from repro.sim.fleet_jax import run_fleet_batch

    signals = compile_fleet_batch(spec, tuple(seeds), dt)
    return run_fleet_batch(spec.models, policy, signals, dt=dt,
                           edge_frac=edge_frac, cloud_frac=cloud_frac,
                           cloud_slots=spec.cloud_concurrency, mesh=mesh,
                           record_trace=record_trace, trace=trace)


def run_registry_sweep(scenarios=None, policies=("DEMS",), seeds=(0,), *,
                       dt: float = 25.0, duration_ms: float | None = None,
                       mesh=None, trace=None, planner: str = "bucketed",
                       donate: bool = False) -> list[dict]:
    """Scenarios × policies × seeds as compiled sweep programs.

    ``planner`` picks the lowering — both produce bitwise-identical
    rows (the fuzz harness in ``tests/test_fuzz_scenarios.py`` holds
    them to it):

    * ``"bucketed"`` (default) — the shape-bucketed multi-program
      planner: :func:`repro.scenarios.compile.compile_registry_groups`
      partitions the sweep into exact-shape buckets
      (:func:`repro.sim.fleet_jax.plan_buckets`), one jit per bucket,
      zero padding.  With ``mesh="auto"`` each bucket's replica axis
      fans over the largest dividing device count; an explicit mesh
      shards every bucket's (replica, edge) grid.
    * ``"padded"`` — the single max-shape padded program
      (:func:`repro.scenarios.compile.compile_registry_batch` +
      one :func:`repro.sim.fleet_jax.run_batch`): the reference baseline
      the bucketed planner is benchmarked and parity-checked against
      (``scaling`` section of ``BENCH_fleet.json``).

    ``scenarios`` accepts registry names and/or ad-hoc
    :class:`~repro.scenarios.spec.ScenarioSpec` instances.  ``donate``
    compiles the sweep programs with their carry buffers donated
    (in-place state updates — same rows, see
    :class:`~repro.sim.fleet_jax.FleetProgram`).  Returns one summary
    dict per run, tagged with its (scenario, policy, seed), in sweep
    order.

    ``trace`` (a :class:`repro.obs.trace.TraceSpec`) threads the flight
    recorder through the sweep: each row dict then also carries a
    ``"trace"`` :class:`~repro.sim.fleet_jax.FleetResult` whose streams
    are re-stacked to that run's own ``[T, E, …]`` layout (lanes of the
    edge-flattened lowering concatenated back along the edge axis; under
    the padded planner the model axis stays padded to the batch maximum,
    padded models simply never count).
    """
    from repro.scenarios.compile import (compile_registry_batch,
                                         compile_registry_groups)
    from repro.sim.fleet_jax import FleetResult, run_batch

    traced = trace is not None and trace.enabled

    def summarize(res, rows):
        final = res.final if traced else res
        out = []
        for row in rows:
            # a run's lanes are its replicas: one for a padded multi-edge
            # batch, one per edge under the edge-flattened lowering —
            # re-stack them into the run's [E, …] state so fleet_summary
            # reduces the per-edge values exactly as the run_fleet path
            # would
            def restack(tree, axis=0):
                parts = [jax.tree.map(lambda a, i=i: a[i], tree)
                         for i in row.lanes]
                return parts[0] if len(parts) == 1 else jax.tree.map(
                    lambda *xs: np.concatenate(
                        [np.asarray(x) for x in xs], axis=axis), *parts)
            state = restack(final)
            d = dict(scenario=row.scenario, policy=row.policy,
                     seed=row.seed, **fleet_summary(state))
            if traced:
                # trace streams are [T, E, …]: lanes rejoin on the edge
                # axis
                d["trace"] = FleetResult(
                    final=state, t_hat=restack(res.t_hat, axis=1),
                    counters=restack(res.counters, axis=1))
            out.append(d)
        return out

    auto = isinstance(mesh, str) and mesh == "auto"

    def auto_mesh(batch):
        r = int(batch.signals.arrive.shape[0])
        n = max(d for d in range(1, jax.device_count() + 1) if r % d == 0)
        return jax.make_mesh((n,), ("replica",)) if n > 1 else None

    if planner == "bucketed":
        by_key = {}
        for batch, rows in compile_registry_groups(
                scenarios, policies, seeds, dt=dt, duration_ms=duration_ms):
            # one host transfer per bucket: the per-row lane slicing in
            # summarize would otherwise issue a device gather per leaf
            # per run (slow when the replica axis is sharded)
            res = jax.device_get(run_batch(
                batch, dt=dt, mesh=auto_mesh(batch) if auto else mesh,
                trace=trace, donate=donate))
            for d in summarize(res, rows):
                by_key[d["scenario"], d["policy"], d["seed"]] = d
        from repro.scenarios.registry import names
        order = tuple(sc if isinstance(sc, str) else sc.name
                      for sc in scenarios) if scenarios is not None \
            else names()
        return [by_key[sc, pol, seed]
                for sc in order for pol in policies for seed in seeds]
    if planner != "padded":
        raise ValueError(f"unknown planner {planner!r}; "
                         f"choose 'bucketed' or 'padded'")

    batch, rows = compile_registry_batch(scenarios, policies, seeds,
                                         dt=dt, duration_ms=duration_ms)
    if auto:
        mesh = auto_mesh(batch)
    res = jax.device_get(run_batch(batch, dt=dt, mesh=mesh, trace=trace,
                                   donate=donate))
    return summarize(res, rows)


def fleet_summary(final) -> dict[str, float]:
    """Scalar fleet-level metrics from a stacked final EdgeState."""
    success = int(np.asarray(final.n_success).sum())
    miss = int(np.asarray(final.n_miss).sum())
    drop = int(np.asarray(final.n_drop).sum())
    settled = max(success + miss + drop, 1)
    return dict(
        completed=success, missed=miss, dropped=drop,
        completion_rate=success / settled,
        qos_utility=float(np.asarray(final.qos_utility).sum()),
        qoe_utility=float(np.asarray(final.qoe_utility).sum()),
        stolen=int(np.asarray(final.n_stolen).sum()),
        peer_offloaded=int(np.asarray(final.n_peer_out).sum()))


def fleet_summary_batch(final) -> list[dict[str, float]]:
    """Per-replica summaries from a ``run_fleet_batch`` final state."""
    n_replicas = np.asarray(final.qos_utility).shape[0]
    return [fleet_summary(jax.tree.map(lambda a: a[r], final))
            for r in range(n_replicas)]
