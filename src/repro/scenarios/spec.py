"""Declarative scenario specifications (fleet control plane, ROADMAP "as
many scenarios as you can imagine").

A :class:`ScenarioSpec` describes *what happens* during a fleet mission —
edge sites on a 2-D plane with coverage zones and heterogeneous speeds,
drones flying waypoint routes (with spawn/despawn churn), arrival-rate
bursts, WAN latency shaping and cloud outages — independently of *how* it
is simulated.  :mod:`repro.scenarios.compile` lowers a spec to

* per-edge :class:`repro.sim.engine.Arrival` streams + latency traces for
  the discrete-event oracle, and
* dense per-tick array signals (drone→edge assignment baked into arrival
  masks, per-edge θ(t) and load multipliers, cloud-up mask) for the
  vmapped fleet simulator in :mod:`repro.sim.fleet_jax`.

All times are milliseconds, positions meters, speeds m/s.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.task import PASSIVE, TABLE1, ModelProfile
from repro.faults.spec import FaultSpec

DEFAULT_SEGMENT_MS = 1_000.0


@dataclasses.dataclass(frozen=True)
class EdgeSite:
    """One base station: position, coverage radius, relative speed.

    ``speed_factor`` scales the edge's *actual and expected* execution
    latency (>1 = slower hardware), modeling heterogeneous Jetson tiers.
    """

    x: float = 0.0
    y: float = 0.0
    radius: float = 1_500.0
    speed_factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class DroneSpec:
    """One drone: a waypoint route plus optional churn window.

    The drone flies the waypoint polyline at ``speed_mps``, ping-ponging
    back and forth; ``speed_mps == 0`` or a single waypoint means it
    hovers at ``waypoints[0]``.  Outside [``spawn_ms``, ``despawn_ms``)
    the drone emits no tasks (churn / dropout).
    """

    waypoints: tuple[tuple[float, float], ...] = ((0.0, 0.0),)
    speed_mps: float = 0.0
    spawn_ms: float = 0.0
    despawn_ms: Optional[float] = None   # None → mission end


@dataclasses.dataclass(frozen=True)
class Burst:
    """Arrival-rate burst: segment rate × ``rate_mult`` during the window."""

    start_ms: float
    end_ms: float
    rate_mult: float = 2.0


@dataclasses.dataclass(frozen=True)
class CloudOutage:
    """Cloud FaaS unavailability window with post-recovery cold starts."""

    start_ms: float
    end_ms: float
    cold_ms: float = 600.0          # penalty on dispatches just after the end
    cold_window_ms: float = 3_000.0


@dataclasses.dataclass(frozen=True)
class ThetaTrapezium:
    """§8.5 trapezium added-latency waveform, optionally per edge subset."""

    low: float = 0.0
    high: float = 400.0
    ramp_up: tuple[float, float] = (60_000.0, 90_000.0)
    ramp_down: tuple[float, float] = (210_000.0, 240_000.0)
    edges: Optional[tuple[int, ...]] = None   # None → every edge


@dataclasses.dataclass(frozen=True)
class BandwidthTrace:
    """Cellular bandwidth shaping (Fig 2c analogue), per edge subset.

    Parameters mirror :func:`repro.sim.network.cellular_bandwidth_trace`;
    the compiled trace applies the *signed* transfer-penalty convention
    (see ``network.py``) identically in the oracle's
    ``CloudLatencyModel.shaped_delta`` and the fleet's dense ``bw``
    signal.  The walk seed derives from ``seed`` alone (not the
    scenario's), so reseeded replicas of one mission share the same radio
    environment.
    """

    seed: int = 7
    lo: float = 0.25
    hi: float = 40.0
    start: float = 18.0
    step_ms: float = 1_000.0
    edges: Optional[tuple[int, ...]] = None   # None → every edge


@dataclasses.dataclass(frozen=True)
class DurationJitter:
    """Stochastic per-(model, tick) execution-duration multipliers.

    Both simulators draw the *same* seeded log-normal sample tables
    (``compile.compile_exec_jitter``): the fleet consumes them as the
    dense ``FleetSignals.exec_jit`` lane; the oracle indexes the
    identical tables through ``network.TableEdgeLatencyModel`` /
    ``TableCloudLatencyModel``, so fleet-vs-oracle agreement holds on
    stochastic scenarios too.  Multipliers have median 1.0
    (``exp(N(0, sigma))``) and scale only the compute body of a task —
    θ(t) and bandwidth shaping stay additive on top, matching the
    oracle's conventions.  ``sigma == 0`` yields *exactly* 1.0, making
    the zero-variance mode bit-identical to ``jitter=None``.

    ``heavy_tail_p`` mixes in Lambda cold-start-like stragglers: with
    that probability a cloud sample is further multiplied by
    ``heavy_tail_mult``.  Clip bounds keep edge samples inside the
    oracle's admissible fraction band.
    """

    edge_sigma: float = 0.10
    cloud_sigma: float = 0.18
    heavy_tail_p: float = 0.0
    heavy_tail_mult: float = 3.0
    edge_clip: tuple[float, float] = (0.68, 1.77)
    cloud_clip: tuple[float, float] = (0.40, 6.0)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A complete mission description, compilable to both simulators."""

    name: str
    duration_ms: float = 300_000.0
    segment_ms: float = DEFAULT_SEGMENT_MS
    model_names: tuple[str, ...] = PASSIVE
    edges: tuple[EdgeSite, ...] = (EdgeSite(),)
    drones: tuple[DroneSpec, ...] = (DroneSpec(), DroneSpec(), DroneSpec())
    bursts: tuple[Burst, ...] = ()
    outages: tuple[CloudOutage, ...] = ()
    theta: Optional[ThetaTrapezium] = None
    bandwidth: Optional[BandwidthTrace] = None
    # each edge's share of the bounded cloud FaaS concurrency: the
    # oracle Simulator's ``cloud_concurrency`` and the fleet simulator's
    # per-edge ``cloud_slots`` (small values → queue-wait under load)
    cloud_concurrency: int = 16
    # stochastic execution durations (None → deterministic Table-1 means)
    jitter: Optional[DurationJitter] = None
    # chaos-engine fault schedule (None → no injected faults); see
    # repro.faults.spec.FaultSpec for the catalogue
    faults: Optional[FaultSpec] = None
    # QoE windows on every model: ``(alpha, beta)`` overrides the
    # Table-1 profiles' (QoS-only) zeros, Table-2 style — live windowed
    # workloads for GEMS policies and the degradation scoreboard
    qoe: Optional[tuple[float, float]] = None
    seed: int = 0

    def __post_init__(self) -> None:
        """Reject out-of-range / contradictory specs with a clear error
        instead of silently compiling garbage signals."""
        if self.duration_ms <= 0.0:
            raise ValueError(
                f"duration_ms must be > 0, got {self.duration_ms}")
        if self.segment_ms <= 0.0:
            raise ValueError(
                f"segment_ms must be > 0, got {self.segment_ms}")
        if not self.edges:
            raise ValueError("a scenario needs at least one edge site")
        if self.cloud_concurrency <= 0:
            raise ValueError(
                f"cloud_concurrency must be >= 1, got "
                f"{self.cloud_concurrency}")
        for e in self.edges:
            if e.radius <= 0.0 or e.speed_factor <= 0.0:
                raise ValueError(
                    f"EdgeSite radius/speed_factor must be > 0: {e}")
        for d in self.drones:
            if d.despawn_ms is not None and d.despawn_ms <= d.spawn_ms:
                raise ValueError(
                    f"DroneSpec despawn_ms must exceed spawn_ms: {d}")
        for b in self.bursts:
            if b.end_ms <= b.start_ms or b.start_ms < 0.0:
                raise ValueError(
                    f"Burst window must satisfy 0 <= start < end: {b}")
            if b.rate_mult <= 0.0:
                raise ValueError(f"Burst rate_mult must be > 0: {b}")
        wins = sorted((o.start_ms, o.end_ms) for o in self.outages)
        for (s, e) in wins:
            if e <= s or s < 0.0:
                raise ValueError(
                    f"CloudOutage window must satisfy 0 <= start < end: "
                    f"[{s}, {e})")
        for (s0, e0), (s1, _) in zip(wins, wins[1:]):
            if s1 < e0:
                raise ValueError(
                    f"overlapping CloudOutage windows: [{s0}, {e0}) and "
                    f"[{s1}, ...)")
        for o in self.outages:
            if o.cold_ms < 0.0 or o.cold_window_ms < 0.0:
                raise ValueError(
                    f"CloudOutage cold_ms/cold_window_ms must be >= 0: {o}")
        j = self.jitter
        if j is not None:
            if j.edge_sigma < 0.0 or j.cloud_sigma < 0.0:
                raise ValueError(
                    f"DurationJitter sigmas must be >= 0: {j}")
            if not 0.0 <= j.heavy_tail_p <= 1.0:
                raise ValueError(
                    f"DurationJitter heavy_tail_p must be in [0, 1]: {j}")
            for name, clip in (("edge_clip", j.edge_clip),
                               ("cloud_clip", j.cloud_clip)):
                if clip[0] < 0.0 or clip[1] < clip[0]:
                    raise ValueError(
                        f"DurationJitter {name} must satisfy "
                        f"0 <= lo <= hi: {clip}")
        if self.qoe is not None:
            alpha, beta = self.qoe
            if not 0.0 < alpha <= 1.0 or beta < 0.0:
                raise ValueError(
                    f"qoe must satisfy 0 < alpha <= 1 and beta >= 0, "
                    f"got {self.qoe}")
        if self.faults is not None:
            # FaultSpec fields self-validate in their own __post_init__;
            # edge indices can only be checked against this spec
            self.faults.validate_edges(self.n_edges)

    @property
    def models(self) -> list[ModelProfile]:
        ms = [TABLE1[n] for n in self.model_names]
        if self.qoe is not None:
            alpha, beta = self.qoe
            ms = [dataclasses.replace(m, qoe_alpha=alpha, qoe_beta=beta)
                  for m in ms]
        return ms

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    @property
    def n_drones(self) -> int:
        return len(self.drones)

    def edge_models(self, e: int) -> list[ModelProfile]:
        """Model table as seen by edge ``e`` (speed factor folded into t)."""
        sf = self.edges[e].speed_factor
        if sf == 1.0:
            return self.models
        return [dataclasses.replace(m, t_edge=m.t_edge * sf)
                for m in self.models]

    def drone_alive(self, d: int, t: float) -> bool:
        dr = self.drones[d]
        end = self.duration_ms if dr.despawn_ms is None else dr.despawn_ms
        return dr.spawn_ms <= t < end

    def reseeded(self, seeds: tuple[int, ...]) -> tuple["ScenarioSpec", ...]:
        """Replicas of this mission differing only in the RNG seed — the
        unit of a :func:`repro.sim.fleet_jax.run_fleet_batch` sweep."""
        return tuple(dataclasses.replace(self, seed=s) for s in seeds)
