"""Drone mobility and edge-coverage geometry on the 2-D plane.

Pure functions from (spec, drone, time) to positions and covering edges —
shared by the oracle and the fleet compilers so both simulators see the
exact same drone→edge handover times.
"""
from __future__ import annotations

import math

from repro.scenarios.spec import DroneSpec, ScenarioSpec


def position(drone: DroneSpec, t_ms: float) -> tuple[float, float]:
    """Drone position at ``t_ms``: ping-pong along the waypoint polyline."""
    wps = drone.waypoints
    if drone.speed_mps <= 0.0 or len(wps) < 2:
        return wps[0]
    seg_len = [math.dist(wps[i], wps[i + 1]) for i in range(len(wps) - 1)]
    total = sum(seg_len)
    if total <= 0.0:
        return wps[0]
    traveled = drone.speed_mps * (t_ms / 1_000.0)
    s = math.fmod(traveled, 2.0 * total)
    if s > total:                       # returning leg of the ping-pong
        s = 2.0 * total - s
    for i, L in enumerate(seg_len):
        if s <= L or i == len(seg_len) - 1:
            f = 0.0 if L == 0.0 else min(s / L, 1.0)
            (x0, y0), (x1, y1) = wps[i], wps[i + 1]
            return (x0 + f * (x1 - x0), y0 + f * (y1 - y0))
        s -= L
    return wps[-1]


def covering_edge(spec: ScenarioSpec, pos: tuple[float, float]) -> int:
    """Index of the edge serving ``pos``: nearest in-coverage site, falling
    back to the nearest site overall when no coverage zone contains it."""
    dists = [math.dist(pos, (e.x, e.y)) for e in spec.edges]
    in_range = [i for i, (d, e) in enumerate(zip(dists, spec.edges))
                if d <= e.radius]
    pool = in_range if in_range else range(len(spec.edges))
    return min(pool, key=lambda i: dists[i])


def assignment(spec: ScenarioSpec, d: int, t_ms: float) -> int:
    """Edge homing drone ``d``'s arrivals at time ``t_ms`` (handover)."""
    return covering_edge(spec, position(spec.drones[d], t_ms))
