"""Named scenario library ("handle as many scenarios as you can imagine").

Each entry is a zero-argument builder returning a fresh
:class:`ScenarioSpec`; ``get(name)`` also accepts overrides (e.g. a
shorter ``duration_ms`` for tests and quick sweeps).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.task import ACTIVE, PASSIVE
from repro.faults import (Brownout, EdgeCrash, FaultSpec, Flood, Jamming,
                          Partition)
from repro.scenarios.spec import (BandwidthTrace, Burst, CloudOutage,
                                  DroneSpec, DurationJitter, EdgeSite,
                                  ScenarioSpec, ThetaTrapezium)


def baseline() -> ScenarioSpec:
    """The paper's 3D-P workload as a degenerate scenario: one edge, three
    hovering drones, no events — compiles bit-for-bit to ``task_stream``."""
    return ScenarioSpec(name="baseline")


def rush_hour() -> ScenarioSpec:
    """Arrival burst: every drone triples its segment rate for a minute
    (VIP convoy passes through) while the fleet keeps steady elsewhere."""
    return ScenarioSpec(
        name="rush-hour",
        edges=(EdgeSite(0, 0), EdgeSite(3_000, 0)),
        drones=(DroneSpec(waypoints=((0.0, 100.0),)),
                DroneSpec(waypoints=((100.0, 0.0),)),
                DroneSpec(waypoints=((3_000.0, 100.0),)),
                DroneSpec(waypoints=((2_900.0, 0.0),))),
        bursts=(Burst(start_ms=60_000.0, end_ms=120_000.0, rate_mult=3.0),))


def roaming_vips() -> ScenarioSpec:
    """Two VIP drones commute across three coverage zones (handover) while
    two station-keeping drones hold the end zones (active workload)."""
    return ScenarioSpec(
        name="roaming-vips",
        model_names=ACTIVE,
        edges=(EdgeSite(0, 0), EdgeSite(2_500, 0), EdgeSite(5_000, 0)),
        drones=(DroneSpec(waypoints=((0.0, 0.0), (5_000.0, 0.0)),
                          speed_mps=25.0),
                DroneSpec(waypoints=((5_000.0, 200.0), (0.0, 200.0)),
                          speed_mps=18.0),
                DroneSpec(waypoints=((100.0, 0.0),)),
                DroneSpec(waypoints=((4_900.0, 0.0),))))


def flaky_cloud() -> ScenarioSpec:
    """§8.5 trapezium WAN latency plus a hard cloud outage with cold
    starts on recovery — the regime where edge-heavy policies win."""
    return ScenarioSpec(
        name="flaky-cloud",
        theta=ThetaTrapezium(),
        outages=(CloudOutage(start_ms=150_000.0, end_ms=180_000.0,
                             cold_ms=900.0, cold_window_ms=5_000.0),))


def hetero_edges() -> ScenarioSpec:
    """Heterogeneous edge tiers: an Orin-class fast site, a Nano-class
    slow site, and a nominal one, each serving local drones."""
    return ScenarioSpec(
        name="hetero-edges",
        edges=(EdgeSite(0, 0, speed_factor=0.7),
               EdgeSite(3_000, 0, speed_factor=1.0),
               EdgeSite(6_000, 0, speed_factor=1.6)),
        drones=tuple(DroneSpec(waypoints=((x, 0.0),))
                     for x in (0.0, 100.0, 3_000.0, 3_100.0, 6_000.0,
                               6_100.0)))


def churn() -> ScenarioSpec:
    """Drone churn: staggered spawns and dropouts (battery swaps, crashes)
    across two sites — arrival load ramps up, shifts, and decays."""
    d = 300_000.0
    return ScenarioSpec(
        name="churn",
        edges=(EdgeSite(0, 0), EdgeSite(3_000, 0)),
        drones=(DroneSpec(waypoints=((0.0, 0.0),), despawn_ms=0.6 * d),
                DroneSpec(waypoints=((100.0, 0.0),), spawn_ms=0.2 * d),
                DroneSpec(waypoints=((200.0, 0.0),), spawn_ms=0.4 * d,
                          despawn_ms=0.8 * d),
                DroneSpec(waypoints=((3_000.0, 0.0),), despawn_ms=0.5 * d),
                DroneSpec(waypoints=((3_100.0, 0.0),), spawn_ms=0.1 * d),
                DroneSpec(waypoints=((3_200.0, 0.0),), spawn_ms=0.5 * d)))


def cloud_crunch() -> ScenarioSpec:
    """Finite cloud pool under pressure: each edge's FaaS share shrinks to
    two concurrent slots while a mid-mission burst quadruples arrivals —
    the GEMS_STRESS-style regime where cloud *queue-wait*, not WAN
    latency, is what the scheduler must adapt around."""
    return ScenarioSpec(
        name="cloud-crunch",
        cloud_concurrency=2,
        bursts=(Burst(start_ms=10_000.0, end_ms=40_000.0, rate_mult=4.0),))


def bw_fade() -> ScenarioSpec:
    """Cellular deep fade: the edge↔cloud link's bandwidth walks far below
    the nominal 20 Mbps (Fig 2c), inflating every transfer by the signed
    penalty convention — edge-leaning policies should win."""
    return ScenarioSpec(
        name="bw-fade",
        bandwidth=BandwidthTrace(seed=11, lo=0.3, hi=6.0, start=2.0))


def duration_jitter() -> ScenarioSpec:
    """Stochastic execution durations (Fig 1 distributions): two edges of
    four drones with log-normal per-(tick, model) duration multipliers on
    both the Jetson-class edge and the Lambda cloud — the fidelity regime
    where *tail* latency, not mean latency, decides deadline hits.
    Multi-edge, so ``*-COOP`` policies get same-sample oracle validation
    through the lockstep :class:`~repro.sim.engine.FleetOracle`."""
    return ScenarioSpec(
        name="duration-jitter",
        edges=(EdgeSite(0, 0), EdgeSite(3_000, 0)),
        drones=(DroneSpec(waypoints=((0.0, 100.0),)),
                DroneSpec(waypoints=((100.0, 0.0),)),
                DroneSpec(waypoints=((3_000.0, 100.0),)),
                DroneSpec(waypoints=((2_900.0, 0.0),))),
        jitter=DurationJitter(edge_sigma=0.10, cloud_sigma=0.18))


def heavy_tail() -> ScenarioSpec:
    """Long-tailed cloud durations (Fig 1b): moderate body jitter plus a
    5 % chance any cloud sample triples (Lambda cold-start-shaped
    stragglers) — p99 deadline-hit is where policies separate."""
    return ScenarioSpec(
        name="heavy-tail",
        jitter=DurationJitter(edge_sigma=0.08, cloud_sigma=0.25,
                              heavy_tail_p=0.05, heavy_tail_mult=3.0))


def flash_crowd() -> ScenarioSpec:
    """Hostile demand spike: a legitimate crowd surge (3× burst) with an
    attacker flood riding inside it — admission control and backpressure
    must shed without starving the real traffic."""
    return ScenarioSpec(
        name="flash-crowd",
        edges=(EdgeSite(0, 0), EdgeSite(3_000, 0)),
        drones=(DroneSpec(waypoints=((0.0, 100.0),)),
                DroneSpec(waypoints=((100.0, 0.0),)),
                DroneSpec(waypoints=((3_000.0, 100.0),)),
                DroneSpec(waypoints=((2_900.0, 0.0),))),
        bursts=(Burst(start_ms=30_000.0, end_ms=90_000.0, rate_mult=3.0),),
        faults=FaultSpec(
            floods=(Flood(start_ms=40_000.0, end_ms=80_000.0,
                          rate_hz=6.0),)))


def ddos_flood() -> ScenarioSpec:
    """Adversarial arrival flood: one edge takes ~25 Hz of junk inference
    requests for a minute — far past its service rate, so survival means
    dropping cheaply and keeping the ledger exact, not keeping up."""
    return ScenarioSpec(
        name="ddos-flood",
        faults=FaultSpec(
            floods=(Flood(start_ms=30_000.0, end_ms=90_000.0,
                          rate_hz=25.0, edges=(0,)),)))


def partition() -> ScenarioSpec:
    """Network partition + edge crash: edge 0 loses its WAN uplink for
    30 s (dispatches park, GEMS migration halts) while edge 1's
    scheduler crashes mid-window (queue flushed, arrivals re-route
    cloud-ward) — the compound-failure regime."""
    return ScenarioSpec(
        name="partition",
        edges=(EdgeSite(0, 0), EdgeSite(3_000, 0)),
        drones=(DroneSpec(waypoints=((0.0, 100.0),)),
                DroneSpec(waypoints=((100.0, 0.0),)),
                DroneSpec(waypoints=((3_000.0, 100.0),)),
                DroneSpec(waypoints=((2_900.0, 0.0),))),
        faults=FaultSpec(
            partitions=(Partition(start_ms=40_000.0, end_ms=70_000.0,
                                  edges=(0,)),),
            crashes=(EdgeCrash(edge=1, start_ms=50_000.0,
                               end_ms=65_000.0),)))


def brownout() -> ScenarioSpec:
    """Correlated cloud brownout: every edge's WAN latency ramps to a
    +350 ms plateau and back (trapezoid layered on θ(t)) — the slow-burn
    degradation where adaptive estimators must steer work edge-ward.
    Runs the ACTIVE workload so QoE windows are live and the
    degradation scoreboard gets a QoE-retention row."""
    return ScenarioSpec(
        name="brownout",
        model_names=ACTIVE,
        qoe=(0.85, 480.0),
        faults=FaultSpec(
            brownouts=(Brownout(start_ms=30_000.0, end_ms=210_000.0,
                                theta_ms=350.0, ramp_ms=20_000.0),)))


SCENARIOS: dict[str, Callable[[], ScenarioSpec]] = {
    "baseline": baseline,
    "rush-hour": rush_hour,
    "roaming-vips": roaming_vips,
    "flaky-cloud": flaky_cloud,
    "hetero-edges": hetero_edges,
    "churn": churn,
    "cloud-crunch": cloud_crunch,
    "bw-fade": bw_fade,
    "duration-jitter": duration_jitter,
    "heavy-tail": heavy_tail,
    "flash-crowd": flash_crowd,
    "ddos-flood": ddos_flood,
    "partition": partition,
    "brownout": brownout,
}


def names() -> tuple[str, ...]:
    return tuple(SCENARIOS)


def get(name: str, **overrides) -> ScenarioSpec:
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; choose from "
                         f"{sorted(SCENARIOS)}")
    spec = SCENARIOS[name]()
    return dataclasses.replace(spec, **overrides) if overrides else spec
