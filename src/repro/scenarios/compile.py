"""Lower a :class:`ScenarioSpec` to simulator inputs.

Two targets, sharing the same arrival-time and handover geometry so the
oracle and the fleet simulator see the same mission:

* :func:`compile_oracle` — per-edge :class:`repro.sim.engine.Arrival`
  streams plus per-edge θ(t) traces and outage windows for the
  discrete-event engine.  For a single static edge with no events the
  generated stream is **bit-for-bit identical** to
  :func:`repro.sim.workloads.task_stream` (same RNG draw order), so every
  existing workload is the degenerate scenario.
* :func:`compile_fleet` — dense per-tick :class:`~repro.sim.fleet_jax.
  FleetSignals` arrays: the drone→edge assignment is baked into the
  arrival mask (handover re-homes future arrivals), edge speed factors
  become per-edge load multipliers, outages become the cloud-up mask and
  a post-outage cold-start bump on θ, and the cellular bandwidth trace
  becomes the dense ``bw`` channel (same signed transfer-penalty
  convention as the oracle's ``CloudLatencyModel.shaped_delta``).

"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.scenarios.mobility import assignment
from repro.scenarios.spec import ScenarioSpec
from repro.sim import network
from repro.sim.engine import Arrival
from repro.sim.fleet_jax import (FleetBatch, FleetSignals,
                                 build_fleet_batch, stack_signals)


@dataclasses.dataclass
class OracleInputs:
    """Compiled inputs for one :class:`repro.sim.engine.Simulator` per edge."""

    spec: ScenarioSpec
    edge_arrivals: list[list[Arrival]]
    theta_fns: list[Callable[[float], float]]
    bw_fns: list[Callable[[float], float]]
    # (start, end, cold_ms, cold_window_ms) per outage — the engine's
    # 4-tuple form, preserving each outage's own cold-start profile
    outages: tuple[tuple[float, float, float, float], ...]


def _theta_fn(spec: ScenarioSpec, e: int) -> Callable[[float], float]:
    th = spec.theta
    if th is None or (th.edges is not None and e not in th.edges):
        return network.constant(0.0)
    return network.trapezium(th.low, th.high, th.ramp_up, th.ramp_down)


def _bw_fn(spec: ScenarioSpec, e: int) -> Callable[[float], float]:
    """Edge ``e``'s cellular bandwidth trace (nominal when unshaped)."""
    b = spec.bandwidth
    if b is None or (b.edges is not None and e not in b.edges):
        return network.constant(network.NOMINAL_BW_MBPS)
    return network.cellular_bandwidth_trace(
        seed=b.seed, duration_ms=spec.duration_ms, step_ms=b.step_ms,
        lo=b.lo, hi=b.hi, start=b.start)


def n_steps(total_ms: float, step_ms: float, what: str = "duration") -> int:
    """Number of ``step_ms`` steps covering ``total_ms``, validated.

    ``int(total / step)`` truncates: a duration not divisible by the step
    (or mere float drift, e.g. ``0.1 * 3``) silently drops the final
    steps.  Round instead, tolerate only float noise, and raise on
    genuinely non-divisible specs so the mission horizon is always exact.
    """
    ratio = total_ms / step_ms
    n = round(ratio)
    if n <= 0 or abs(ratio - n) > 1e-6 * max(1.0, abs(ratio)):
        raise ValueError(
            f"{what} {total_ms} ms is not an integer multiple of the "
            f"{step_ms} ms step (ratio {ratio!r}); pick divisible values "
            "so no ticks are silently dropped")
    return int(n)


def _arrival_times(spec: ScenarioSpec, d: int,
                   rng: np.random.Generator) -> tuple[float, list[float]]:
    """Base (phase, segment times) for drone ``d`` — task_stream protocol."""
    phase = float(rng.uniform(0, spec.segment_ms))
    n_segments = n_steps(spec.duration_ms, spec.segment_ms, "duration")
    times = [s * spec.segment_ms + phase for s in range(n_segments)]
    return phase, times


def _burst_times(spec: ScenarioSpec, phase: float) -> list[float]:
    """Extra arrival times so total rate = rate_mult × base inside bursts."""
    extra: list[float] = []
    for b in spec.bursts:
        if b.rate_mult <= 1.0:
            continue
        step = spec.segment_ms / (b.rate_mult - 1.0)
        t = b.start_ms + (phase % step)
        while t < min(b.end_ms, spec.duration_ms):
            extra.append(t)
            t += step
    return extra


def _emit(spec: ScenarioSpec, sink, seed=None) -> None:
    """Walk every arrival event once, calling ``sink(t, d, e, order)``.

    The base loop replicates ``workloads.task_stream`` draw-for-draw (one
    shared RNG: per-drone phase, then per-segment model permutation), so a
    1-edge static no-event spec compiles to the identical stream.  Burst
    extras draw from per-drone child generators to leave the base stream
    untouched.
    """
    rng = np.random.default_rng(spec.seed if seed is None else seed)
    m = len(spec.model_names)
    extras: list[tuple[float, int]] = []
    for d in range(spec.n_drones):
        phase, times = _arrival_times(spec, d, rng)
        for t in times:
            if t >= spec.duration_ms:
                continue
            order = rng.permutation(m)
            if not spec.drone_alive(d, t):
                continue                      # churn: draw but do not emit
            sink(t, d, assignment(spec, d, t), order)
        extras.extend((t, d) for t in _burst_times(spec, phase))
    for t, d in sorted(extras):
        erng = np.random.default_rng([spec.seed, 0x6275, d, int(t)])
        order = erng.permutation(m)
        if spec.drone_alive(d, t):
            sink(t, d, assignment(spec, d, t), order)


def compile_exec_jitter(spec: ScenarioSpec, dt: float = 25.0,
                        n_ticks: int | None = None
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Per-(tick, model) execution-duration multiplier tables.

    Returns ``(edge_tab, cloud_tab)``, each ``float32 [T, M]`` with
    median-1.0 log-normal samples per :class:`~repro.scenarios.spec.
    DurationJitter` — or exact ones when ``spec.jitter`` is ``None`` (and
    bit-identically when every sigma is zero, since ``exp(N(0, 0)) ==
    1.0``).  Both simulators consume the *same* tables: the fleet as the
    dense ``FleetSignals.exec_jit`` lane, the oracle through
    :class:`repro.sim.network.TableEdgeLatencyModel` /
    :class:`~repro.sim.network.TableCloudLatencyModel` indexing by
    ``min(now // dt, T - 1)`` — so a task executing at time ``t`` draws
    the same multiplier in either backend.
    """
    m = len(spec.model_names)
    if n_ticks is None:
        n_ticks = n_steps(spec.duration_ms, dt, "duration")
    j = spec.jitter
    if j is None:
        ones = np.ones((n_ticks, m), np.float32)
        return ones, ones.copy()
    rng = np.random.default_rng([spec.seed, 0x4A17, j.seed])

    def lognormal(sigma: float, clip: tuple[float, float]) -> np.ndarray:
        x = np.exp(rng.normal(0.0, sigma, size=(n_ticks, m)))
        return np.clip(x, clip[0], clip[1])

    edge = lognormal(j.edge_sigma, j.edge_clip)
    cloud = lognormal(j.cloud_sigma, j.cloud_clip)
    if j.heavy_tail_p > 0.0:
        # Lambda cold-start-like stragglers: rare multiplicative spikes
        tail = rng.random(size=(n_ticks, m)) < j.heavy_tail_p
        cloud = np.where(
            tail, np.clip(cloud * j.heavy_tail_mult, *j.cloud_clip), cloud)
    return edge.astype(np.float32), cloud.astype(np.float32)


def compile_oracle(spec: ScenarioSpec) -> OracleInputs:
    """Per-edge arrival streams + traces for the discrete-event engine."""
    edge_models = [spec.edge_models(e) for e in range(spec.n_edges)]
    edge_arrivals: list[list[Arrival]] = [[] for _ in range(spec.n_edges)]

    def sink(t: float, d: int, e: int, order) -> None:
        for k in order:
            edge_arrivals[e].append(
                Arrival(time=t, model=edge_models[e][int(k)], drone=d))

    _emit(spec, sink)
    return OracleInputs(
        spec=spec,
        edge_arrivals=edge_arrivals,
        theta_fns=[_theta_fn(spec, e) for e in range(spec.n_edges)],
        bw_fns=[_bw_fn(spec, e) for e in range(spec.n_edges)],
        outages=tuple((o.start_ms, o.end_ms, o.cold_ms, o.cold_window_ms)
                      for o in spec.outages))


def compile_fleet(spec: ScenarioSpec, dt: float = 25.0) -> FleetSignals:
    """Dense per-tick array signals for :func:`repro.sim.fleet_jax.run_fleet`.

    The fleet simulator inserts at most one task per (edge, model) per
    tick; coincident same-model arrivals (colliding drone phases, burst
    extras landing on base segment times) would silently collapse on a
    boolean mask and deflate the load versus the oracle, so each extra
    task spills to the next tick with a free (edge, model) slot — a few
    ``dt`` of skew against sub-second deadlines, but an exact task count.
    """
    import jax.numpy as jnp

    m = len(spec.model_names)
    n_edges = spec.n_edges
    n_ticks = n_steps(spec.duration_ms, dt, "duration")
    times = np.arange(n_ticks, dtype=np.float32) * dt

    arrive = np.zeros((n_ticks, n_edges, m), dtype=bool)

    def sink(t: float, d: int, e: int, order) -> None:
        tick = min(int(t / dt), n_ticks - 1)
        for k in order:
            tk = tick
            while tk < n_ticks - 1 and arrive[tk, e, k]:
                tk += 1
            if arrive[tk, e, k]:     # horizon full → spill backwards so a
                tk = tick            # burst running to the end still keeps
                while tk > 0 and arrive[tk, e, k]:   # its task count
                    tk -= 1
            arrive[tk, e, k] = True

    _emit(spec, sink)

    # per-edge θ(t) and cellular bandwidth, evaluated vectorized over the
    # whole tick grid (array-native trace fns — no per-tick Python loop);
    # post-outage cold starts appear as a θ bump so the first
    # post-recovery dispatches pay the container-warmup price.
    theta = np.zeros((n_ticks, n_edges), dtype=np.float32)
    bw = np.empty((n_ticks, n_edges), dtype=np.float32)
    for e in range(n_edges):
        theta[:, e] = network.sample_trace(_theta_fn(spec, e), times)
        bw[:, e] = network.sample_trace(_bw_fn(spec, e), times)
    cloud_up = np.ones(n_ticks, dtype=bool)
    for o in spec.outages:
        down = (times >= o.start_ms) & (times < o.end_ms)
        cloud_up &= ~down
        cold = (times >= o.end_ms) & (times < o.end_ms + o.cold_window_ms)
        theta[cold, :] += o.cold_ms

    load_mult = np.broadcast_to(
        np.array([e.speed_factor for e in spec.edges], np.float32),
        (n_ticks, n_edges)).copy()

    rng = np.random.default_rng([spec.seed, 0x0dde])
    order = rng.permuted(np.tile(np.arange(m), (n_ticks, n_edges, 1)),
                         axis=2).astype(np.int32)

    # sampled execution-duration multipliers, shared with the oracle's
    # table latency models; axis -1 is (edge, cloud).  Every edge sees
    # the same [T, M] tables so a peer-offloaded task keeps its draw.
    ej, cj = compile_exec_jitter(spec, dt, n_ticks)
    exec_jit = np.broadcast_to(
        np.stack([ej, cj], axis=-1)[:, None, :, :],
        (n_ticks, n_edges, m, 2)).copy()

    return FleetSignals(
        times=jnp.asarray(times), theta=jnp.asarray(theta),
        bw=jnp.asarray(bw), arrive=jnp.asarray(arrive),
        order=jnp.asarray(order),
        load_mult=jnp.asarray(load_mult), cloud_up=jnp.asarray(cloud_up),
        valid=jnp.ones((n_ticks, n_edges), bool),
        exec_jit=jnp.asarray(exec_jit))


def compile_fleet_batch(spec: ScenarioSpec, seeds: tuple[int, ...],
                        dt: float = 25.0) -> FleetSignals:
    """Stacked signals ``[R, …]`` for one scenario across ``seeds`` —
    input to :func:`repro.sim.fleet_jax.run_fleet_batch`, which runs the
    whole seed sweep as a single compiled program."""
    return stack_signals([compile_fleet(sp, dt)
                          for sp in spec.reseeded(tuple(seeds))])


@dataclasses.dataclass(frozen=True)
class SweepRun:
    """Index row of one run in a registry batch.

    ``lanes`` are the run's replica indices in the batch: a single lane
    normally, one lane per edge under the edge-flattened lowering (see
    :func:`compile_registry_batch`).
    """

    scenario: str
    policy: str
    seed: int
    lanes: tuple[int, ...] = (0,)


def _slice_edge(sig: FleetSignals, e: int) -> FleetSignals:
    """One edge's signals as a 1-edge mission (edge axis kept, length 1)."""
    return FleetSignals(
        times=sig.times, theta=sig.theta[:, e:e + 1],
        bw=sig.bw[:, e:e + 1], arrive=sig.arrive[:, e:e + 1],
        order=sig.order[:, e:e + 1], load_mult=sig.load_mult[:, e:e + 1],
        cloud_up=sig.cloud_up, valid=sig.valid[:, e:e + 1],
        exec_jit=sig.exec_jit[:, e:e + 1])


def compile_registry_batch(scenarios=None, policies=("DEMS",),
                           seeds=(0,), *, dt: float = 25.0,
                           duration_ms: float | None = None
                           ) -> tuple[FleetBatch, list[SweepRun]]:
    """Lower scenarios × policies × seeds to **one** compiled program.

    Every named registry scenario (all of them by default) is compiled
    per seed, padded to the batch's max (ticks, edges, models) shape with
    validity masks, and paired with its policy's runtime
    :class:`~repro.sim.fleet_jax.PolicyParams` and its own
    ``cloud_concurrency`` pool — so the whole sweep executes as a single
    jitted :func:`repro.sim.fleet_jax.run_batch` call instead of one
    compile per (scenario, policy).

    When no requested policy is cooperative, edges never interact, so the
    batch is **edge-flattened**: each (run, edge) becomes its own 1-edge
    replica — zero edge padding, per-edge results bitwise identical to
    the multi-edge vmap — and each :class:`SweepRun` row carries its
    ``lanes``.  Returns the batch plus the run index, in replica order.
    """
    from repro.scenarios.registry import get, names
    from repro.sim.fleet_jax import _resolve_policy

    flatten = not any(_resolve_policy(p).cooperation for p in policies)
    runs, rows, lane = [], [], 0
    sig_cache: dict = {}    # policies share a (scenario, seed)'s signals
    for sc in (tuple(scenarios) if scenarios else names()):
        spec = get(sc) if duration_ms is None else get(
            sc, duration_ms=duration_ms)
        for pol in policies:
            for seed in seeds:
                sp = dataclasses.replace(spec, seed=seed)
                if (sc, seed) not in sig_cache:
                    sig = compile_fleet(sp, dt)
                    sig_cache[sc, seed] = [
                        _slice_edge(sig, e) for e in range(sp.n_edges)
                    ] if flatten else [sig]
                sigs = sig_cache[sc, seed]
                runs.extend((sp.models, pol, s, sp.cloud_concurrency)
                            for s in sigs)
                lanes = tuple(range(lane, lane + len(sigs)))
                lane += len(sigs)
                rows.append(SweepRun(scenario=sc, policy=pol, seed=seed,
                                     lanes=lanes))
    return build_fleet_batch(runs, dt=dt), rows


def compile_registry_groups(scenarios=None, policies=("DEMS",),
                            seeds=(0,), *, dt: float = 25.0,
                            duration_ms: float | None = None
                            ) -> list[tuple[FleetBatch, list[SweepRun]]]:
    """The sweep as exact-shape groups — the single-device lowering.

    On one device the single padded batch of
    :func:`compile_registry_batch` buys no parallelism, yet every replica
    still pays max-shape padding and (with any cooperative policy in the
    mix) the un-flattened multi-edge step + peer-offload rounds — the
    full registry ran *slower* batched than looped.  This lowering
    partitions the same sweep into groups keyed by exact
    ``(ticks, edges, models, cooperative)`` shape: non-cooperative runs
    are edge-flattened per group (1-edge replicas, zero edge padding),
    cooperative runs group by their true multi-edge shape, and
    peer-offload rounds compile only into cooperative groups.  Within a
    group stacking is exact — no padding at all — so each group's
    ``run_batch`` rows still equal the per-scenario ``run_fleet`` loop
    bitwise.

    Returns ``(batch, rows)`` per group; each row's ``lanes`` index into
    its *own* group's batch.  Rows across all groups partition the sweep.
    """
    from repro.scenarios.registry import get, names
    from repro.sim.fleet_jax import _resolve_policy

    groups: dict = {}
    sig_cache: dict = {}
    for sc in (tuple(scenarios) if scenarios else names()):
        spec = get(sc) if duration_ms is None else get(
            sc, duration_ms=duration_ms)
        for pol in policies:
            coop = _resolve_policy(pol).cooperation
            for seed in seeds:
                sp = dataclasses.replace(spec, seed=seed)
                if (sc, seed) not in sig_cache:
                    sig = compile_fleet(sp, dt)
                    sig_cache[sc, seed] = (
                        sig, [_slice_edge(sig, e)
                              for e in range(sp.n_edges)])
                whole, slices = sig_cache[sc, seed]
                sigs = [whole] if coop else slices
                t, e, m = sigs[0].arrive.shape
                g = groups.setdefault((t, e, m, coop),
                                      dict(runs=[], rows=[], lane=0))
                g["runs"].extend((sp.models, pol, s, sp.cloud_concurrency)
                                 for s in sigs)
                lanes = tuple(range(g["lane"], g["lane"] + len(sigs)))
                g["lane"] += len(sigs)
                g["rows"].append(SweepRun(scenario=sc, policy=pol,
                                          seed=seed, lanes=lanes))
    return [(build_fleet_batch(g["runs"], dt=dt), g["rows"])
            for g in groups.values()]
