"""Lower a :class:`ScenarioSpec` to simulator inputs.

Two targets, sharing the same arrival-time and handover geometry so the
oracle and the fleet simulator see the same mission:

* :func:`compile_oracle` — per-edge :class:`repro.sim.engine.Arrival`
  streams plus per-edge θ(t) traces and outage windows for the
  discrete-event engine.  For a single static edge with no events the
  generated stream is **bit-for-bit identical** to
  :func:`repro.sim.workloads.task_stream` (same RNG draw order), so every
  existing workload is the degenerate scenario.
* :func:`compile_fleet` — dense per-tick :class:`~repro.sim.fleet_jax.
  FleetSignals` arrays: the drone→edge assignment is baked into the
  arrival mask (handover re-homes future arrivals), edge speed factors
  become per-edge load multipliers, outages become the cloud-up mask and
  a post-outage cold-start bump on θ, and the cellular bandwidth trace
  becomes the dense ``bw`` channel (same signed transfer-penalty
  convention as the oracle's ``CloudLatencyModel.shaped_delta``).

"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro import faults as fl
from repro.scenarios.mobility import assignment
from repro.scenarios.spec import ScenarioSpec
from repro.sim import network
from repro.sim.engine import Arrival
from repro.sim.fleet_jax import (FleetBatch, FleetSignals,
                                 build_fleet_batch, stack_signals)


@dataclasses.dataclass
class OracleInputs:
    """Compiled inputs for one :class:`repro.sim.engine.Simulator` per edge."""

    spec: ScenarioSpec
    edge_arrivals: list[list[Arrival]]
    theta_fns: list[Callable[[float], float]]
    bw_fns: list[Callable[[float], float]]
    # (start, end, cold_ms, cold_window_ms) per outage — the engine's
    # 4-tuple form, preserving each outage's own cold-start profile
    outages: tuple[tuple[float, float, float, float], ...]
    # chaos-engine lowering (None without a fault schedule): per-edge
    # outage lists (fleet-wide outages + that edge's partition windows as
    # zero-cold outages) and per-edge crash windows for the engine's
    # edge_down_windows
    edge_outages: list | None = None
    crashes: list | None = None


def _theta_fn(spec: ScenarioSpec, e: int) -> Callable[[float], float]:
    th = spec.theta
    if th is None or (th.edges is not None and e not in th.edges):
        return network.constant(0.0)
    return network.trapezium(th.low, th.high, th.ramp_up, th.ramp_down)


def _bw_fn(spec: ScenarioSpec, e: int) -> Callable[[float], float]:
    """Edge ``e``'s cellular bandwidth trace (nominal when unshaped)."""
    b = spec.bandwidth
    if b is None or (b.edges is not None and e not in b.edges):
        return network.constant(network.NOMINAL_BW_MBPS)
    return network.cellular_bandwidth_trace(
        seed=b.seed, duration_ms=spec.duration_ms, step_ms=b.step_ms,
        lo=b.lo, hi=b.hi, start=b.start)


def n_steps(total_ms: float, step_ms: float, what: str = "duration") -> int:
    """Number of ``step_ms`` steps covering ``total_ms``, validated.

    ``int(total / step)`` truncates: a duration not divisible by the step
    (or mere float drift, e.g. ``0.1 * 3``) silently drops the final
    steps.  Round instead, tolerate only float noise, and raise on
    genuinely non-divisible specs so the mission horizon is always exact.
    """
    ratio = total_ms / step_ms
    n = round(ratio)
    if n <= 0 or abs(ratio - n) > 1e-6 * max(1.0, abs(ratio)):
        raise ValueError(
            f"{what} {total_ms} ms is not an integer multiple of the "
            f"{step_ms} ms step (ratio {ratio!r}); pick divisible values "
            "so no ticks are silently dropped")
    return int(n)


def _arrival_times(spec: ScenarioSpec, d: int,
                   rng: np.random.Generator) -> tuple[float, list[float]]:
    """Base (phase, segment times) for drone ``d`` — task_stream protocol."""
    phase = float(rng.uniform(0, spec.segment_ms))
    n_segments = n_steps(spec.duration_ms, spec.segment_ms, "duration")
    times = [s * spec.segment_ms + phase for s in range(n_segments)]
    return phase, times


def _burst_times(spec: ScenarioSpec, phase: float) -> list[float]:
    """Extra arrival times so total rate = rate_mult × base inside bursts."""
    extra: list[float] = []
    for b in spec.bursts:
        if b.rate_mult <= 1.0:
            continue
        step = spec.segment_ms / (b.rate_mult - 1.0)
        t = b.start_ms + (phase % step)
        while t < min(b.end_ms, spec.duration_ms):
            extra.append(t)
            t += step
    return extra


def _emit(spec: ScenarioSpec, sink, seed=None) -> None:
    """Walk every arrival event once, calling ``sink(t, d, e, order)``.

    The base loop replicates ``workloads.task_stream`` draw-for-draw (one
    shared RNG: per-drone phase, then per-segment model permutation), so a
    1-edge static no-event spec compiles to the identical stream.  Burst
    extras draw from per-drone child generators to leave the base stream
    untouched.
    """
    rng = np.random.default_rng(spec.seed if seed is None else seed)
    m = len(spec.model_names)
    extras: list[tuple[float, int]] = []
    for d in range(spec.n_drones):
        phase, times = _arrival_times(spec, d, rng)
        for t in times:
            if t >= spec.duration_ms:
                continue
            order = rng.permutation(m)
            if not spec.drone_alive(d, t):
                continue                      # churn: draw but do not emit
            sink(t, d, assignment(spec, d, t), order)
        extras.extend((t, d) for t in _burst_times(spec, phase))
    for t, d in sorted(extras):
        erng = np.random.default_rng([spec.seed, 0x6275, d, int(t)])
        order = erng.permutation(m)
        if spec.drone_alive(d, t):
            sink(t, d, assignment(spec, d, t), order)


def compile_exec_jitter(spec: ScenarioSpec, dt: float = 25.0,
                        n_ticks: int | None = None
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Per-(tick, model) execution-duration multiplier tables.

    Returns ``(edge_tab, cloud_tab)``, each ``float32 [T, M]`` with
    median-1.0 log-normal samples per :class:`~repro.scenarios.spec.
    DurationJitter` — or exact ones when ``spec.jitter`` is ``None`` (and
    bit-identically when every sigma is zero, since ``exp(N(0, 0)) ==
    1.0``).  Both simulators consume the *same* tables: the fleet as the
    dense ``FleetSignals.exec_jit`` lane, the oracle through
    :class:`repro.sim.network.TableEdgeLatencyModel` /
    :class:`~repro.sim.network.TableCloudLatencyModel` indexing by
    ``min(now // dt, T - 1)`` — so a task executing at time ``t`` draws
    the same multiplier in either backend.
    """
    m = len(spec.model_names)
    if n_ticks is None:
        n_ticks = n_steps(spec.duration_ms, dt, "duration")
    j = spec.jitter
    if j is None:
        ones = np.ones((n_ticks, m), np.float32)
        return ones, ones.copy()
    rng = np.random.default_rng([spec.seed, 0x4A17, j.seed])

    def lognormal(sigma: float, clip: tuple[float, float]) -> np.ndarray:
        x = np.exp(rng.normal(0.0, sigma, size=(n_ticks, m)))
        return np.clip(x, clip[0], clip[1])

    edge = lognormal(j.edge_sigma, j.edge_clip)
    cloud = lognormal(j.cloud_sigma, j.cloud_clip)
    if j.heavy_tail_p > 0.0:
        # Lambda cold-start-like stragglers: rare multiplicative spikes
        tail = rng.random(size=(n_ticks, m)) < j.heavy_tail_p
        cloud = np.where(
            tail, np.clip(cloud * j.heavy_tail_mult, *j.cloud_clip), cloud)
    return edge.astype(np.float32), cloud.astype(np.float32)


class SignalWindowBuilder:
    """Incremental, dt-aligned assembly of :class:`FleetSignals` windows.

    The seam between the scenario compiler and the online control plane
    (:class:`repro.serve.controller.FleetController`): telemetry events
    land in their ``dt`` tick — arrivals spill *forward* to the next
    free (edge, model) cell, exactly the batch compiler's convention;
    channel updates (θ, bandwidth, edge load, cloud availability) hold
    their last value forward — and :meth:`emit_window` pops the next
    ``n`` ticks as a window for
    :meth:`repro.sim.fleet_jax.FleetProgram.step_chunk`.

    Two modes share the code path:

    * **compiler mode** (``horizon_ticks`` set): the buffer is the whole
      mission and arrivals that run off the end spill *backwards* from
      their original tick (a burst reaching the horizon keeps its task
      count).  :func:`compile_fleet` is exactly this: feed every event,
      bulk-load the dense channels, emit one horizon-length window.
      The ``order`` lane defaults to a placeholder the compiler always
      overwrites via :meth:`load_dense`.
    * **streaming mode** (no horizon): the buffer grows with telemetry,
      nothing ever spills backwards, and events older than the emit
      cursor clamp forward to it (the past cannot be rewritten — the
      documented late-telemetry contract).  The ``order`` lane draws a
      per-tick seeded permutation (``[order_seed, 0x0dde, tick]``), so
      insertion order is reproducible across restarts regardless of
      window boundaries.

    ``exec_jit`` defaults to the deterministic ×1.0 lane in both modes
    (live cloud variability enters through θ/bandwidth telemetry);
    compiler mode overwrites it with the sampled tables.
    """

    # channels with a forward-hold current value (name → per-row shape fn)
    _HELD = ("theta", "bw", "load_mult", "cloud_up", "exec_jit",
             "edge_up", "link_up")

    def __init__(self, n_edges: int, n_models: int, *, dt: float = 25.0,
                 horizon_ticks: int | None = None, start_tick: int = 0,
                 order_seed: int = 0):
        self.n_edges, self.n_models = int(n_edges), int(n_models)
        self.dt = float(dt)
        self.horizon = horizon_ticks
        self.order_seed = order_seed
        self._base = int(start_tick)   # absolute tick of buffer row 0
        self._rows = 0                 # allocated rows past the base
        self._hi = int(start_tick)     # one past the last tick touched
        e, m = self.n_edges, self.n_models
        self._cur = dict(
            theta=np.zeros(e, np.float32),
            bw=np.full(e, network.NOMINAL_BW_MBPS, np.float32),
            load_mult=np.ones(e, np.float32),
            cloud_up=True,
            exec_jit=np.ones((e, m, 2), np.float32),
            edge_up=np.ones(e, bool),
            link_up=np.ones(e, bool))
        self._buf: dict[str, np.ndarray] = {}
        self._ensure_rows(horizon_ticks if horizon_ticks is not None else 64)

    # -- buffer management -------------------------------------------------
    def _default_order(self, tick0: int, n: int) -> np.ndarray:
        e, m = self.n_edges, self.n_models
        if self.horizon is not None:
            # compiler-mode placeholder: always overwritten by load_dense
            return np.broadcast_to(np.arange(m, dtype=np.int32),
                                   (n, e, m)).copy()
        return np.stack([
            np.random.default_rng([self.order_seed, 0x0dde, t]).permuted(
                np.tile(np.arange(m), (e, 1)), axis=1)
            for t in range(tick0, tick0 + n)]).astype(np.int32)

    def _ensure_rows(self, rows: int) -> None:
        if rows <= self._rows:
            return
        rows = max(rows, 2 * self._rows)
        if self.horizon is not None:
            rows = min(rows, self.horizon - self._base)
        n_new = rows - self._rows
        e, m = self.n_edges, self.n_models
        cur = self._cur
        grow = dict(
            arrive=np.zeros((n_new, e, m), bool),
            theta=np.broadcast_to(cur["theta"], (n_new, e)).copy(),
            bw=np.broadcast_to(cur["bw"], (n_new, e)).copy(),
            load_mult=np.broadcast_to(cur["load_mult"], (n_new, e)).copy(),
            cloud_up=np.full(n_new, cur["cloud_up"], bool),
            valid=np.ones((n_new, e), bool),
            exec_jit=np.broadcast_to(cur["exec_jit"],
                                     (n_new, e, m, 2)).copy(),
            edge_up=np.broadcast_to(cur["edge_up"], (n_new, e)).copy(),
            link_up=np.broadcast_to(cur["link_up"], (n_new, e)).copy(),
            order=self._default_order(self._base + self._rows, n_new))
        self._buf = grow if not self._buf else {
            k: np.concatenate([self._buf[k], grow[k]]) for k in grow}
        self._rows = rows

    def _tick(self, t_ms: float) -> int:
        """The dt tick a timestamp lands in: clamped into the horizon in
        compiler mode, forward to the emit cursor in streaming mode."""
        tk = int(t_ms / self.dt)
        if self.horizon is not None:
            tk = min(tk, self.horizon - 1)
        return max(tk, self._base)

    def _touch(self, tk: int) -> int:
        """Allocate through absolute tick ``tk``; return its row."""
        self._ensure_rows(tk - self._base + 1)
        self._hi = max(self._hi, tk + 1)
        return tk - self._base

    @property
    def cursor(self) -> int:
        """The first tick the next :meth:`emit_window` will cover."""
        return self._base

    @property
    def pending_ticks(self) -> int:
        """Ticks of telemetry seen beyond the emit cursor."""
        return self._hi - self._base

    # -- telemetry ingestion ----------------------------------------------
    def add_arrival(self, t_ms: float, edge: int, model: int) -> int:
        """One task arrival; returns the tick it landed in after spill.

        The fleet step inserts at most one task per (edge, model) per
        tick, so coincident same-model arrivals spill forward to the
        next free cell (and, in compiler mode only, backwards when the
        horizon is full) — an exact task count at the price of a few
        ``dt`` of skew.
        """
        tk = self._tick(t_ms)
        r = self._touch(tk)
        a = self._buf["arrive"]
        if self.horizon is not None:
            last = self.horizon - 1 - self._base
            while r < last and a[r, edge, model]:
                r += 1
            if a[r, edge, model]:      # horizon full → spill backwards so
                r = tk - self._base    # a burst running to the end still
                while r > 0 and a[r, edge, model]:   # keeps its task count
                    r -= 1
        else:
            while True:
                if a[r, edge, model]:
                    r = self._touch(self._base + r + 1)
                    a = self._buf["arrive"]
                    continue
                break
        a[r, edge, model] = True
        self._hi = max(self._hi, self._base + r + 1)
        return self._base + r

    def set_theta(self, t_ms: float, value: float,
                  edge: int | None = None) -> None:
        """Added WAN latency θ from ``t_ms`` on (one edge, or all)."""
        self._set("theta", t_ms, value, edge)

    def set_bandwidth(self, t_ms: float, mbps: float,
                      edge: int | None = None) -> None:
        """Cellular bandwidth from ``t_ms`` on (one edge, or all)."""
        self._set("bw", t_ms, mbps, edge)

    def set_load(self, t_ms: float, mult: float,
                 edge: int | None = None) -> None:
        """Edge execution-time multiplier from ``t_ms`` on."""
        self._set("load_mult", t_ms, mult, edge)

    def set_cloud_up(self, t_ms: float, up: bool) -> None:
        """Cloud FaaS availability from ``t_ms`` on."""
        r = self._touch(self._tick(t_ms))
        self._buf["cloud_up"][r:] = bool(up)
        self._cur["cloud_up"] = bool(up)

    def set_edge_up(self, t_ms: float, up: bool,
                    edge: int | None = None) -> None:
        """Edge liveness from ``t_ms`` on — False crashes the edge
        (queue flush + no admission) in the tick program."""
        self._set("edge_up", t_ms, bool(up), edge)

    def set_link_up(self, t_ms: float, up: bool,
                    edge: int | None = None) -> None:
        """Edge↔cloud link state from ``t_ms`` on — False partitions
        the edge (cloud dispatch parks, GEMS migration halts)."""
        self._set("link_up", t_ms, bool(up), edge)

    def _set(self, field: str, t_ms: float, value: float,
             edge: int | None) -> None:
        r = self._touch(self._tick(t_ms))
        sl = slice(None) if edge is None else edge
        self._buf[field][r:, sl] = value
        self._cur[field][sl] = value

    def load_dense(self, field: str, values: np.ndarray,
                   start_tick: int = 0) -> None:
        """Bulk-write a dense channel block (the batch compiler's path).

        ``values`` covers ticks ``[start_tick, start_tick + len)``;
        held channels update their hold from the last written row, so
        streaming past the block continues its final value.
        """
        values = np.asarray(values)
        if start_tick < self._base:
            raise ValueError(
                f"load_dense({field!r}) starts at tick {start_tick}, "
                f"before the emit cursor {self._base} — emitted windows "
                f"cannot be rewritten")
        self._touch(start_tick + len(values) - 1)
        r = start_tick - self._base
        self._buf[field][r:r + len(values)] = values
        if field in self._HELD:
            if field == "cloud_up":
                self._cur[field] = bool(values[-1])
            else:
                self._cur[field][...] = values[-1]

    # -- window emission ---------------------------------------------------
    def emit_window(self, n_ticks: int) -> FleetSignals:
        """Pop ticks ``[cursor, cursor + n_ticks)`` as dense signals.

        Ticks with no telemetry carry each channel's held value and no
        arrivals; the cursor advances, so these ticks are final.
        """
        import jax.numpy as jnp

        self._ensure_rows(n_ticks)
        t0 = self._base
        times = np.arange(t0, t0 + n_ticks, dtype=np.float32) * self.dt
        window = FleetSignals(
            times=jnp.asarray(times),
            theta=jnp.asarray(self._buf["theta"][:n_ticks]),
            bw=jnp.asarray(self._buf["bw"][:n_ticks]),
            arrive=jnp.asarray(self._buf["arrive"][:n_ticks]),
            order=jnp.asarray(self._buf["order"][:n_ticks]),
            load_mult=jnp.asarray(self._buf["load_mult"][:n_ticks]),
            cloud_up=jnp.asarray(self._buf["cloud_up"][:n_ticks]),
            valid=jnp.asarray(self._buf["valid"][:n_ticks]),
            exec_jit=jnp.asarray(self._buf["exec_jit"][:n_ticks]),
            edge_up=jnp.asarray(self._buf["edge_up"][:n_ticks]),
            link_up=jnp.asarray(self._buf["link_up"][:n_ticks]))
        self._buf = {k: v[n_ticks:].copy() for k, v in self._buf.items()}
        self._rows -= n_ticks
        self._base += n_ticks
        self._hi = max(self._hi, self._base)
        return window


def compile_oracle(spec: ScenarioSpec) -> OracleInputs:
    """Per-edge arrival streams + traces for the discrete-event engine."""
    edge_models = [spec.edge_models(e) for e in range(spec.n_edges)]
    edge_arrivals: list[list[Arrival]] = [[] for _ in range(spec.n_edges)]

    def sink(t: float, d: int, e: int, order) -> None:
        for k in order:
            edge_arrivals[e].append(
                Arrival(time=t, model=edge_models[e][int(k)], drone=d))

    _emit(spec, sink)
    theta_fns = [_theta_fn(spec, e) for e in range(spec.n_edges)]
    bw_fns = [_bw_fn(spec, e) for e in range(spec.n_edges)]
    outages = tuple((o.start_ms, o.end_ms, o.cold_ms, o.cold_window_ms)
                    for o in spec.outages)
    edge_outages = crashes = None
    faults = spec.faults
    if faults is not None:
        # floods go through the same sink protocol as the benign stream,
        # in the same order as compile_fleet feeds them
        for t, d, e, order in fl.flood_events(
                spec.seed, faults, spec.n_edges, len(spec.model_names),
                spec.duration_ms, spec.n_drones):
            sink(t, d, e, order)
        # jamming/brownout θ overlays and bandwidth caps wrap the base
        # traces — the identical callables compile_fleet samples densely
        theta_fns = [
            (lambda t, base=base, ov=fl.theta_overlay_fn(faults, e):
             base(t) + ov(t))
            for e, base in enumerate(theta_fns)]
        bw_fns = [
            (lambda t, base=base, cap=fl.bw_cap_fn(faults, e):
             np.minimum(base(t), cap(t)))
            for e, base in enumerate(bw_fns)]
        parts = fl.partition_windows(faults, spec.n_edges)
        edge_outages = [
            tuple(sorted(outages + tuple((s, t, 0.0, 0.0)
                                         for (s, t) in parts[e])))
            for e in range(spec.n_edges)]
        crashes = fl.crash_windows(faults, spec.n_edges)
    return OracleInputs(
        spec=spec,
        edge_arrivals=edge_arrivals,
        theta_fns=theta_fns,
        bw_fns=bw_fns,
        outages=outages,
        edge_outages=edge_outages,
        crashes=crashes)


def compile_fleet(spec: ScenarioSpec, dt: float = 25.0) -> FleetSignals:
    """Dense per-tick array signals for :func:`repro.sim.fleet_jax.run_fleet`.

    "Compile the whole horizon" over the same
    :class:`SignalWindowBuilder` the online controller streams through:
    every arrival event feeds :meth:`~SignalWindowBuilder.add_arrival`
    (coincident same-model arrivals would silently collapse on a boolean
    mask and deflate the load versus the oracle, so each extra task
    spills to the next free (edge, model) cell — a few ``dt`` of skew
    against sub-second deadlines, but an exact task count), the dense
    channels are bulk-loaded, and the mission pops out as one
    horizon-length window.
    """
    m = len(spec.model_names)
    n_edges = spec.n_edges
    n_ticks = n_steps(spec.duration_ms, dt, "duration")
    times = np.arange(n_ticks, dtype=np.float32) * dt

    b = SignalWindowBuilder(n_edges, m, dt=dt, horizon_ticks=n_ticks)

    def sink(t: float, d: int, e: int, order) -> None:
        for k in order:
            b.add_arrival(t, e, int(k))

    _emit(spec, sink)
    faults = spec.faults
    if faults is not None:
        # the identical seeded flood events the oracle compiler feeds,
        # in the identical order
        for t, d, e, order in fl.flood_events(
                spec.seed, faults, n_edges, m, spec.duration_ms,
                spec.n_drones):
            sink(t, d, e, order)

    # per-edge θ(t) and cellular bandwidth, evaluated vectorized over the
    # whole tick grid (array-native trace fns — no per-tick Python loop);
    # post-outage cold starts appear as a θ bump so the first
    # post-recovery dispatches pay the container-warmup price.
    theta = np.zeros((n_ticks, n_edges), dtype=np.float32)
    bw = np.empty((n_ticks, n_edges), dtype=np.float32)
    for e in range(n_edges):
        theta[:, e] = network.sample_trace(_theta_fn(spec, e), times)
        bw[:, e] = network.sample_trace(_bw_fn(spec, e), times)
        if faults is not None:
            # the same overlay/cap callables compile_oracle wraps around
            # its trace fns, sampled on the tick grid
            theta[:, e] += fl.theta_overlay_fn(faults, e)(times)
            bw[:, e] = np.minimum(bw[:, e],
                                  fl.bw_cap_fn(faults, e)(times))
    cloud_up = np.ones(n_ticks, dtype=bool)
    for o in spec.outages:
        down = (times >= o.start_ms) & (times < o.end_ms)
        cloud_up &= ~down
        cold = (times >= o.end_ms) & (times < o.end_ms + o.cold_window_ms)
        theta[cold, :] += o.cold_ms

    load_mult = np.broadcast_to(
        np.array([e.speed_factor for e in spec.edges], np.float32),
        (n_ticks, n_edges)).copy()

    rng = np.random.default_rng([spec.seed, 0x0dde])
    order = rng.permuted(np.tile(np.arange(m), (n_ticks, n_edges, 1)),
                         axis=2).astype(np.int32)

    # sampled execution-duration multipliers, shared with the oracle's
    # table latency models; axis -1 is (edge, cloud).  Every edge sees
    # the same [T, M] tables so a peer-offloaded task keeps its draw.
    ej, cj = compile_exec_jitter(spec, dt, n_ticks)
    exec_jit = np.broadcast_to(
        np.stack([ej, cj], axis=-1)[:, None, :, :],
        (n_ticks, n_edges, m, 2)).copy()

    if faults is not None:
        edge_up = fl.edge_up_dense(faults, times, n_edges)
        link_up = fl.link_up_dense(faults, times, n_edges)
    else:
        edge_up = np.ones((n_ticks, n_edges), dtype=bool)
        link_up = np.ones((n_ticks, n_edges), dtype=bool)

    for field, vals in (("theta", theta), ("bw", bw),
                        ("cloud_up", cloud_up), ("load_mult", load_mult),
                        ("order", order), ("exec_jit", exec_jit),
                        ("edge_up", edge_up), ("link_up", link_up)):
        b.load_dense(field, vals)
    return b.emit_window(n_ticks)


def compile_fleet_batch(spec: ScenarioSpec, seeds: tuple[int, ...],
                        dt: float = 25.0) -> FleetSignals:
    """Stacked signals ``[R, …]`` for one scenario across ``seeds`` —
    input to :func:`repro.sim.fleet_jax.run_fleet_batch`, which runs the
    whole seed sweep as a single compiled program."""
    return stack_signals([compile_fleet(sp, dt)
                          for sp in spec.reseeded(tuple(seeds))])


@dataclasses.dataclass(frozen=True)
class SweepRun:
    """Index row of one run in a registry batch.

    ``lanes`` are the run's replica indices in the batch: a single lane
    normally, one lane per edge under the edge-flattened lowering (see
    :func:`compile_registry_batch`).
    """

    scenario: str
    policy: str
    seed: int
    lanes: tuple[int, ...] = (0,)


def _slice_edge(sig: FleetSignals, e: int) -> FleetSignals:
    """One edge's signals as a 1-edge mission (edge axis kept, length 1)."""
    return FleetSignals(
        times=sig.times, theta=sig.theta[:, e:e + 1],
        bw=sig.bw[:, e:e + 1], arrive=sig.arrive[:, e:e + 1],
        order=sig.order[:, e:e + 1], load_mult=sig.load_mult[:, e:e + 1],
        cloud_up=sig.cloud_up, valid=sig.valid[:, e:e + 1],
        exec_jit=sig.exec_jit[:, e:e + 1],
        edge_up=sig.edge_up[:, e:e + 1], link_up=sig.link_up[:, e:e + 1])


def _sweep_specs(scenarios, duration_ms) -> list[ScenarioSpec]:
    """Resolve a sweep's scenario list: registry names and/or ad-hoc
    :class:`ScenarioSpec` instances (the fuzz harness's entry), all of
    the registry when ``None``, with an optional ``duration_ms``
    override.  Spec names must be unique — they key the sweep's rows."""
    from repro.scenarios.registry import get, names

    specs = [sc if isinstance(sc, ScenarioSpec) else get(sc)
             for sc in (tuple(scenarios) if scenarios is not None
                        else names())]
    if duration_ms is not None:
        specs = [dataclasses.replace(sp, duration_ms=duration_ms)
                 for sp in specs]
    seen = {sp.name for sp in specs}
    if len(seen) != len(specs):
        raise ValueError("sweep scenarios must have unique names, got "
                         f"{[sp.name for sp in specs]}")
    return specs


def compile_registry_batch(scenarios=None, policies=("DEMS",),
                           seeds=(0,), *, dt: float = 25.0,
                           duration_ms: float | None = None
                           ) -> tuple[FleetBatch, list[SweepRun]]:
    """Lower scenarios × policies × seeds to **one** compiled program.

    Every scenario (each named registry entry by default; ad-hoc
    :class:`ScenarioSpec` instances are accepted too) is compiled per
    seed, padded to the batch's max (ticks, edges, models) shape with
    validity masks, and paired with its policy's runtime
    :class:`~repro.sim.fleet_jax.PolicyParams` and its own
    ``cloud_concurrency`` pool — so the whole sweep executes as a single
    jitted :func:`repro.sim.fleet_jax.run_batch` call instead of one
    compile per (scenario, policy).

    When no requested policy is cooperative, edges never interact, so the
    batch is **edge-flattened**: each (run, edge) becomes its own 1-edge
    replica — zero edge padding, per-edge results bitwise identical to
    the multi-edge vmap — and each :class:`SweepRun` row carries its
    ``lanes``.  Returns the batch plus the run index, in replica order.
    """
    from repro.sim.fleet_jax import _resolve_policy

    flatten = not any(_resolve_policy(p).cooperation for p in policies)
    runs, rows, lane = [], [], 0
    sig_cache: dict = {}    # policies share a (scenario, seed)'s signals
    for spec in _sweep_specs(scenarios, duration_ms):
        sc = spec.name
        for pol in policies:
            for seed in seeds:
                sp = dataclasses.replace(spec, seed=seed)
                if (sc, seed) not in sig_cache:
                    sig = compile_fleet(sp, dt)
                    sig_cache[sc, seed] = [
                        _slice_edge(sig, e) for e in range(sp.n_edges)
                    ] if flatten else [sig]
                sigs = sig_cache[sc, seed]
                runs.extend((sp.models, pol, s, sp.cloud_concurrency)
                            for s in sigs)
                lanes = tuple(range(lane, lane + len(sigs)))
                lane += len(sigs)
                rows.append(SweepRun(scenario=sc, policy=pol, seed=seed,
                                     lanes=lanes))
    return build_fleet_batch(runs, dt=dt), rows


def compile_registry_groups(scenarios=None, policies=("DEMS",),
                            seeds=(0,), *, dt: float = 25.0,
                            duration_ms: float | None = None
                            ) -> list[tuple[FleetBatch, list[SweepRun]]]:
    """The sweep as exact-shape buckets — the shape-bucketed planner.

    The single padded batch of :func:`compile_registry_batch` makes
    every replica pay max-shape padding and (with any cooperative policy
    in the mix) the un-flattened multi-edge step + peer-offload rounds —
    the full registry ran *slower* batched than looped.  This lowering
    routes the same sweep through
    :func:`repro.sim.fleet_jax.plan_buckets`: non-cooperative runs are
    edge-flattened (1-edge replicas, zero edge padding), cooperative
    runs bucket by their true multi-edge shape, and peer-offload rounds
    compile only into cooperative buckets.  Within a bucket stacking is
    exact — no padding at all — so each bucket's ``run_batch`` rows
    still equal the per-scenario ``run_fleet`` loop bitwise.

    Returns ``(batch, rows)`` per bucket; each row's ``lanes`` index
    into its *own* bucket's batch.  Rows across all buckets partition
    the sweep.  Like :func:`compile_registry_batch`, ``scenarios`` may
    mix registry names with ad-hoc :class:`ScenarioSpec` instances.
    """
    from repro.sim.fleet_jax import _resolve_policy, plan_buckets

    runs, tags = [], []
    sig_cache: dict = {}
    for spec in _sweep_specs(scenarios, duration_ms):
        sc = spec.name
        for pol in policies:
            coop = _resolve_policy(pol).cooperation
            for seed in seeds:
                sp = dataclasses.replace(spec, seed=seed)
                if (sc, seed) not in sig_cache:
                    sig = compile_fleet(sp, dt)
                    sig_cache[sc, seed] = (
                        sig, [_slice_edge(sig, e)
                              for e in range(sp.n_edges)])
                whole, slices = sig_cache[sc, seed]
                for s in ([whole] if coop else slices):
                    runs.append((sp.models, pol, s, sp.cloud_concurrency))
                    tags.append((sc, pol, seed))
    out = []
    for batch, idxs in plan_buckets(runs, dt=dt):
        # a run's edge-flattened lanes land in one bucket (same shape,
        # same policy), in order — regroup them under their sweep row
        rows: dict = {}
        for lane, i in enumerate(idxs):
            rows.setdefault(tags[i], []).append(lane)
        out.append((batch, [SweepRun(scenario=sc, policy=pol, seed=seed,
                                     lanes=tuple(lanes))
                            for (sc, pol, seed), lanes in rows.items()]))
    return out
