"""AdamW in pure JAX (no optax dependency).

Moments are stored in a configurable dtype — bf16 for the ≥100B configs so
optimizer state fits the per-device HBM budget (see EXPERIMENTS.md
§Dry-run), f32 otherwise.  State shards exactly like the parameters (same
pytree structure → same PartitionSpecs).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + \
                self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - self.lr * delta
            return (new_p.astype(p.dtype), m32.astype(m.dtype),
                    v32.astype(v.dtype))

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)
