"""Training loop over the model zoo (CPU-runnable on reduced configs)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.pipeline import FastSyntheticLM
from repro.models.model import Model
from repro.train.optimizer import AdamW, AdamWState
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class TrainState:
    params: dict
    opt_state: AdamWState
    step: int = 0


def make_train_step(model: Model, opt: AdamW) -> Callable:
    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return loss, new_params, new_opt
    return step


def train(cfg: ArchConfig, *, steps: int = 100, batch: int = 8,
          seq_len: int = 128, lr: float = 3e-3, seed: int = 0,
          log_every: int = 20, checkpoint_path: Optional[str] = None,
          log=print) -> tuple[TrainState, list[float]]:
    model = Model(cfg)
    opt = AdamW(lr=lr)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    step_fn = make_train_step(model, opt)
    data = FastSyntheticLM(vocab=cfg.vocab, seq_len=seq_len, batch=batch,
                           seed=seed).batches()
    losses = []
    t0 = time.time()
    for i in range(steps):
        raw = next(data)
        b = {"tokens": jnp.asarray(raw["tokens"]),
             "labels": jnp.asarray(raw["labels"])}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros((batch, cfg.n_frames, cfg.d_model))
        if cfg.family == "vlm":
            b["patches"] = jnp.zeros((batch, cfg.n_image_tokens,
                                      cfg.d_model))
        loss, params, opt_state = step_fn(params, opt_state, b)
        losses.append(float(loss))
        if i % log_every == 0 or i == steps - 1:
            log(f"step {i:4d} loss {float(loss):.4f} "
                f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    state = TrainState(params=params, opt_state=opt_state, step=steps)
    if checkpoint_path:
        ckpt.save(checkpoint_path, params)
        log(f"checkpoint → {checkpoint_path}.npz")
    return state, losses
