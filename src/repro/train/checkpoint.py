"""Minimal dependency-free checkpointing (npz + JSON treedef)."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def save(path: str, pytree) -> None:
    leaves, treedef = jax.tree.flatten(pytree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path + ".npz",
             **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})
    with open(path + ".tree.json", "w") as f:
        json.dump({"treedef": str(treedef), "n": len(leaves)}, f)


def load(path: str, like) -> object:
    """Restore into the structure of ``like`` (shapes must match)."""
    data = np.load(path + ".npz")
    leaves_like, treedef = jax.tree.flatten(like)
    n = len(leaves_like)
    loaded = [data[f"leaf_{i}"] for i in range(n)]
    for a, b in zip(loaded, leaves_like):
        if tuple(a.shape) != tuple(np.shape(b)):
            raise ValueError(f"shape mismatch {a.shape} vs {np.shape(b)}")
    return jax.tree.unflatten(treedef, loaded)
