"""Production mesh construction (single-pod 16×16 and 2-pod 2×16×16).

A function, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init —
``dryrun.py`` must set ``XLA_FLAGS`` before importing anything jax).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def _auto_axis_kwargs(n_axes: int) -> dict:
    """``axis_types=Auto`` where available; older jax has no AxisType and
    treats every mesh axis as Auto anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_kwargs(len(axes)))


def make_host_mesh():
    """Whatever this process actually has (tests / CPU smoke)."""
    n = jax.device_count()
    return jax.make_mesh((n, 1), ("data", "model"), **_auto_axis_kwargs(2))
