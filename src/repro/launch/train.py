"""Training launcher: ``python -m repro.launch.train --arch granite-3-2b``.

On this CPU container it trains the *reduced* family variant end-to-end
(data pipeline → AdamW → checkpoint).  On a real TPU slice, pass
``--full`` to build the production config and mesh — the step function is
the same one the dry-run compiles for 256/512 chips.
"""
from __future__ import annotations

import argparse

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the full production config (TPU slices only)")
    args = ap.parse_args()

    cfg = ARCHS[args.arch] if args.full else reduced(ARCHS[args.arch])
    state, losses = train(cfg, steps=args.steps, batch=args.batch,
                          seq_len=args.seq, lr=args.lr,
                          checkpoint_path=args.ckpt)
    print(f"final loss {losses[-1]:.4f} after {state.step} steps")


if __name__ == "__main__":
    main()
