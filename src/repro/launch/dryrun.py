import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices form the production meshes (16×16 single-pod,
2×16×16 multi-pod); every step function must lower, SPMD-partition and
compile, and its ``memory_analysis()`` must fit a v5e's 16 GB HBM.

Per combo this driver records:
  * compile wall time, per-device memory (args/outputs/temps),
  * the collective schedule (kinds, shapes, bytes — §Roofline input),
  * cost_analysis + delta-method FLOPs/bytes extrapolation
    (two small *unrolled* compiles; see roofline/analysis.py),
  * the three roofline terms and the dominant bottleneck.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh both --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.registry import ARCHS
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import named_sharding, sharding_rules
from repro.models.model import Model
from repro.roofline import analysis as RA
from repro.train.optimizer import AdamW

#                 name          seq      global_batch  kind
SHAPES = {
    "train_4k":    (4_096,    256, "train"),
    "prefill_32k": (32_768,    32, "prefill"),
    "decode_32k":  (32_768,   128, "decode"),
    "long_500k":   (524_288,    1, "decode"),
}

SKIPS: dict[tuple[str, str], str] = {
    (a, "long_500k"): "pure full-attention (no SWA claimed by the source "
                      "model card) — quadratic attention cannot serve 500k"
    for a in ("grok-1-314b", "qwen3-moe-30b-a3b", "llava-next-34b")
}
SKIPS[("whisper-medium", "long_500k")] = (
    "enc-dec audio model; 500k-token decode is out of family scope")

BIG_OPT_THRESHOLD = 50e9   # params above this use bf16 AdamW moments
MICROBATCH_THRESHOLD = 20e9  # params above this gradient-accumulate


def n_micro_for(cfg: ArchConfig, shape_name: str) -> int:
    """Gradient-accumulation factor for the train shape: ≥100B models
    split the 1M-token global batch into 8 microbatches, ≥20B into 4 —
    keeping activation temps inside a v5e's HBM."""
    if SHAPES[shape_name][2] != "train":
        return 1
    n = cfg.param_count()
    base = 16 if n > 200e9 else 8 if n > 30e9 else \
        4 if n > MICROBATCH_THRESHOLD else 2 if n > 6e9 else 1
    if cfg.remat_policy == "dots" and n > MICROBATCH_THRESHOLD:
        base *= 2          # dots-remat keeps more residents per microbatch
    return min(base, 16)


def delta_unit(cfg: ArchConfig) -> int:
    """Smallest repeatable layer pattern for the delta method."""
    if cfg.family == "ssm":
        return cfg.slstm_every
    if cfg.family == "hybrid":
        return cfg.attn_every
    return 1


def with_layers(cfg: ArchConfig, units: int, unroll: bool) -> ArchConfig:
    u = delta_unit(cfg)
    repl = dict(n_layers=u * units, unroll_layers=unroll)
    if cfg.family == "encdec":
        repl["enc_layers"] = units
    return dataclasses.replace(cfg, **repl)


def full_depth_units(cfg: ArchConfig) -> float:
    """Full depth measured in delta units (fractional for zamba's tail)."""
    return cfg.n_layers / delta_unit(cfg)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    seq, batch, kind = SHAPES[shape_name]
    sd = jax.ShapeDtypeStruct
    if kind in ("train", "prefill"):
        b = {"tokens": sd((batch, seq), jnp.int32)}
        if kind == "train":
            b["labels"] = sd((batch, seq), jnp.int32)
        if cfg.family == "encdec":
            b["frames"] = sd((batch, cfg.n_frames, cfg.d_model),
                             jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            b["patches"] = sd((batch, cfg.n_image_tokens, cfg.d_model),
                              jnp.dtype(cfg.dtype))
        return b
    return {"token": sd((batch, 1), jnp.int32),
            "pos": sd((), jnp.int32)}


def batch_logical(cfg: ArchConfig, key: str) -> tuple:
    return {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
        "token": ("batch", None),
        "pos": (),
        "frames": ("batch", "frames", "embed"),
        "patches": ("batch", None, "embed"),
    }[key]


def cache_logical(key: str, ndim: int) -> tuple:
    if key in ("k", "v", "xk", "xv"):
        if ndim == 5:
            return (None, "batch", "kv_seq", "kv_heads", None)
    if key in ("m_c", "m_n"):        # (G, per, B, H, ...)
        return (None, None, "batch") + (None,) * (ndim - 3)
    if key.startswith("s_"):         # (G, B, H, pd)
        return (None, "batch") + (None,) * (ndim - 2)
    if key == "state":               # (G, k, B, H, P, N)
        return (None, None, "batch") + (None,) * (ndim - 3)
    if key == "tail_state":          # (T, B, H, P, N)
        return (None, "batch") + (None,) * (ndim - 2)
    return (None,) * ndim


def max_seq_for(cfg: ArchConfig, shape_name: str) -> int:
    seq, _, _ = SHAPES[shape_name]
    if cfg.family == "vlm":
        return seq + cfg.n_image_tokens
    return seq


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build(cfg: ArchConfig, shape_name: str, mesh, n_micro: int = 0):
    """Returns (step_fn, arg_specs, arg_shardings).

    ``n_micro`` overrides the microbatch factor — the roofline's delta
    compiles pass the *full-depth* config's factor, since their reduced
    1–2-layer configs would otherwise resolve to 1 (and the extrapolation
    would then double-scale)."""
    seq, batch, kind = SHAPES[shape_name]
    if cfg.family == "moe":
        # group-wise dispatch: one group per data-parallel shard
        n_data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        cfg = dataclasses.replace(cfg, moe_groups=n_data)
        n_model = mesh.shape.get("model", 1)
        if cfg.expert_split == -1:   # resolve "auto" against the mesh
            cfg = dataclasses.replace(
                cfg, expert_split=max(1, n_model // cfg.n_experts))
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(model.init, rng)
    pspecs = model.param_specs()

    def shard_of(shape_struct, logical):
        return named_sharding(shape_struct.shape, logical, mesh)

    params_sh = jax.tree.map(
        lambda s, l: shard_of(s, l), param_shapes, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    bspecs = input_specs(cfg, shape_name)
    batch_sh = {k: shard_of(v, batch_logical(cfg, k))
                for k, v in bspecs.items()}

    if kind == "train":
        opt = AdamW(moment_dtype=("bfloat16" if cfg.param_count() >
                                  BIG_OPT_THRESHOLD else "float32"))
        opt_shapes = jax.eval_shape(opt.init, param_shapes)
        opt_sh = jax.tree.map(
            lambda s: named_sharding(s.shape, (None,) * s.ndim, mesh)
            if s.ndim == 0 else None, opt_shapes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        # moments shard like their parameters
        opt_sh = type(opt_shapes)(
            step=named_sharding((), (), mesh),
            mu=jax.tree.map(lambda s, l: shard_of(s, l), opt_shapes.mu,
                            pspecs,
                            is_leaf=lambda x: isinstance(
                                x, jax.ShapeDtypeStruct)),
            nu=jax.tree.map(lambda s, l: shard_of(s, l), opt_shapes.nu,
                            pspecs,
                            is_leaf=lambda x: isinstance(
                                x, jax.ShapeDtypeStruct)))

        n_micro = n_micro or n_micro_for(cfg, shape_name)

        def grads_of(params, b):
            return jax.value_and_grad(model.loss)(params, b)

        def step(params, opt_state, b):
            if n_micro == 1:
                loss, grads = grads_of(params, b)
            else:
                bm = jax.tree.map(
                    lambda a: a.reshape(n_micro, a.shape[0] // n_micro,
                                        *a.shape[1:]), b)
                zeros = jax.tree.map(jnp.zeros_like, params)
                if cfg.unroll_layers:
                    # delta compiles measure ONE microbatch; the roofline
                    # scales by n_micro (see roofline_combo)
                    loss, grads = grads_of(
                        params, jax.tree.map(lambda a: a[0], bm))
                else:
                    def micro(acc, mb):
                        l, g = grads_of(params, mb)
                        return jax.tree.map(jnp.add, acc, g), l
                    grads, losses = jax.lax.scan(micro, zeros, bm)
                    grads = jax.tree.map(lambda g: g / n_micro, grads)
                    loss = losses.mean()
            new_params, new_opt = opt.update(grads, opt_state, params)
            return loss, new_params, new_opt

        return step, (param_shapes, opt_shapes, bspecs), \
            (params_sh, opt_sh, batch_sh)

    if kind == "prefill":
        ms = max_seq_for(cfg, shape_name)

        def step(params, b):
            return model.prefill(params, b, ms)

        return step, (param_shapes, bspecs), (params_sh, batch_sh)

    # decode
    ms = max_seq_for(cfg, shape_name)
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(batch, ms))
    cache_sh = {k: shard_of(v, cache_logical(k, v.ndim))
                for k, v in cache_shapes.items()}

    def step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return step, (param_shapes, cache_shapes, bspecs["token"],
                  bspecs["pos"]), \
        (params_sh, cache_sh, batch_sh["token"], batch_sh["pos"])


def compile_combo(cfg: ArchConfig, shape_name: str, mesh) -> dict:
    """Lower + compile; return stats."""
    t0 = time.time()
    kind = SHAPES[shape_name][2]
    # donation: train aliases params+opt into their updates; decode
    # aliases the KV/state cache (otherwise XLA double-buffers it — a
    # whole extra cache copy in temps, e.g. +10.8 GB for qwen2 decode)
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[kind]
    with sharding_rules(mesh):
        step, specs, shardings = build(cfg, shape_name, mesh)
        jitted = jax.jit(step, in_shardings=shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_total = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = RA.collective_bytes(hlo, body_trip_count=cfg.n_layers)
    return {
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_total, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_bytes": (mem.argument_size_in_bytes
                            + mem.temp_size_in_bytes),
        },
        "cost_flops_body_once": cost.get("flops", 0.0),
        "cost_bytes_body_once": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "n_devices": mesh.devices.size,
    }


def roofline_combo(cfg: ArchConfig, shape_name: str, mesh,
                   coll_full: float = 0.0) -> dict:
    """Delta-method FLOPs/bytes + roofline terms.

    ``coll_full`` — collective bytes parsed from the *full scanned*
    compile (body × trip count).  Preferred over the delta extrapolation:
    unrolled layer bodies slice sharded caches with static indices, which
    GSPMD turns into per-layer gathers the production scan never issues.
    """
    seq, batch, _ = SHAPES[shape_name]
    vals = {}
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[
        SHAPES[shape_name][2]]
    nm_full = n_micro_for(cfg, shape_name)
    for units in (1, 2):
        dcfg = with_layers(cfg, units, unroll=True)
        with sharding_rules(mesh):
            step, specs, shardings = build(dcfg, shape_name, mesh,
                                           n_micro=nm_full)
            compiled = jax.jit(step, in_shardings=shardings,
                               donate_argnums=donate).lower(
                *specs).compile()
        cost = compiled.cost_analysis() or {}
        coll = RA.collective_bytes(compiled.as_text(), body_trip_count=1)
        vals[units] = (cost.get("flops", 0.0),
                       cost.get("bytes accessed", 0.0), coll["total"])
    lf = full_depth_units(cfg)
    nm = n_micro_for(cfg, shape_name)
    flops = RA.extrapolate(vals[1][0], vals[2][0], 1, 2, lf) * nm
    hbm = RA.extrapolate(vals[1][1], vals[2][1], 1, 2, lf) * nm
    coll_delta = RA.extrapolate(vals[1][2], vals[2][2], 1, 2, lf) * nm
    coll_b = coll_full if coll_full > 0 else coll_delta
    terms = RA.RooflineTerms.build(flops, hbm, coll_b)
    mf_global = RA.model_flops(cfg, shape_name, seq, batch)
    mf_per_dev = mf_global / mesh.devices.size
    return {
        "delta_units": {str(k): v for k, v in vals.items()},
        "collective_bytes_delta": coll_delta,
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm,
        "collective_bytes_per_device": coll_b,
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "bottleneck": terms.bottleneck,
        "model_flops_per_device": mf_per_dev,
        "model_vs_hlo_flops": (mf_per_dev / flops) if flops else None,
    }


def variant_for(cfg: ArchConfig, shape: str,
                opt: bool = False) -> ArchConfig:
    """long_500k on attention archs runs the sliding-window serving
    variant (sub-quadratic; window-sized ring cache) — DESIGN.md §4.
    ``opt`` enables the beyond-paper §Perf optimizations."""
    if shape == "long_500k" and cfg.long_context_window:
        cfg = dataclasses.replace(cfg,
                                  sliding_window=cfg.long_context_window)
    if opt and SHAPES[shape][2] == "decode":
        cfg = dataclasses.replace(cfg, opt_decode=True)
    if opt and cfg.family == "moe":
        cfg = dataclasses.replace(cfg, expert_split=-1)  # auto vs mesh
    if opt and SHAPES[shape][2] == "train":
        cfg = dataclasses.replace(cfg, remat_policy="dots")
    return cfg


def run(arch: str, shape: str, meshes: list[str], out_dir: str,
        do_roofline: bool, opt: bool = False) -> dict:
    cfg = variant_for(ARCHS[arch], shape, opt=opt)
    result = {"arch": arch, "shape": shape, "opt": opt}
    if (arch, shape) in SKIPS:
        result["skipped"] = SKIPS[(arch, shape)]
        print(f"[skip] {arch} × {shape}: {result['skipped']}")
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}__{shape}.json"), "w") as f:
            json.dump(result, f, indent=1)
        return result
    for mesh_kind in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        key = f"mesh_{mesh_kind}"
        try:
            result[key] = compile_combo(cfg, shape, mesh)
            m = result[key]["memory"]
            print(f"[ok]   {arch} × {shape} × {mesh_kind}: "
                  f"compile {result[key]['compile_s']}s, "
                  f"args {m['argument_bytes'] / 1e9:.2f} GB, "
                  f"temps {m['temp_bytes'] / 1e9:.2f} GB/device, "
                  f"coll {result[key]['collective_bytes']['total'] / 1e9:.2f}"
                  f" GB")
        except Exception as e:  # noqa: BLE001 — record and continue
            result[key] = {"ok": False, "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
            print(f"[FAIL] {arch} × {shape} × {mesh_kind}: {e}")
    if do_roofline and "single" in meshes and \
            result.get("mesh_single", {}).get("ok"):
        try:
            mesh = make_production_mesh(multi_pod=False)
            coll_full = result["mesh_single"]["collective_bytes"]["total"]
            result["roofline"] = roofline_combo(cfg, shape, mesh,
                                                coll_full=coll_full)
            r = result["roofline"]
            print(f"       roofline: compute {r['compute_s'] * 1e3:.2f} ms, "
                  f"memory {r['memory_s'] * 1e3:.2f} ms, "
                  f"collective {r['collective_s'] * 1e3:.2f} ms "
                  f"→ {r['bottleneck']}-bound")
        except Exception as e:  # noqa: BLE001
            result["roofline"] = {"error": f"{type(e).__name__}: {e}",
                                  "traceback":
                                      traceback.format_exc()[-2000:]}
            print(f"[FAIL] roofline {arch} × {shape}: {e}")
    os.makedirs(out_dir, exist_ok=True)
    suffix = "__opt" if opt else ""
    path = os.path.join(out_dir, f"{arch}__{shape}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch name or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="enable beyond-paper §Perf optimizations")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" or args.all \
        else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" or args.all \
        else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            r = run(arch, shape, meshes, args.out,
                    do_roofline=not args.no_roofline, opt=args.opt)
            for k, v in r.items():
                if isinstance(v, dict) and v.get("ok") is False:
                    n_fail += 1
    print(f"\ndone; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
