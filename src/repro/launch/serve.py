"""Serving launcher: the paper's scheduler over live model inference.

``python -m repro.launch.serve --policy GEMS --duration 15`` registers
three reduced zoo models as the Ocularone DNS (HV/DEV/BP roles), measures
their p95 latencies, and streams frame-rate tasks through the chosen
policy — the §8.8 field validation without a drone.

Two backends share the measured profiles:

* ``--backend thread`` (default) — the Python :class:`~repro.serve.
  engine.ServeEngine`: real jitted forward passes execute on a worker
  thread per task.
* ``--backend fleet`` — the compiled online control plane
  (:class:`repro.serve.controller.FleetController`): the same frame
  stream is scheduled by the jitted tick program window-by-window, with
  per-tick decision records, flight-recorder tails, and checkpointed
  crash restart (``--checkpoint``).  ``--snapshot-out`` dumps the final
  ``metrics_snapshot()`` as JSON (the CI smoke artifact).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import signal
import time

import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.schedulers import ALL_POLICIES, make_policy
from repro.core.task import ModelProfile
from repro.serve.engine import ServableModel, ServeEngine, run_stream


def probe_p95(model: ServableModel, iters: int = 20) -> float:
    """Warm up + measure a servable model's p95 latency [ms].

    The common calibration both backends build their profiles from: the
    first call hits any residual compile cost, so the percentile is
    taken over ``iters`` steady-state invocations.
    """
    ts = []
    for _ in range(iters):
        t0 = time.monotonic()
        model.run()
        ts.append((time.monotonic() - t0) * 1e3)
    return float(np.percentile(ts, 95))


def build_roles(cloud_concurrency: int = 4
                ) -> tuple[dict[str, ServableModel], dict[str, float]]:
    """Register the Ocularone DNS roles and calibrate their profiles.

    Returns ``(models, fps)``: servable models re-profiled from their
    measured p95 (deadline, edge/cloud latencies) and each role's target
    frame rate.
    """
    roles = {"HV": ("starcoder2-3b", 0.7, 3.0, 125, 1, 25),
             "DEV": ("granite-3-2b", 0.4, 5.0, 100, 1, 26),
             "BP": ("xlstm-1.3b", 0.3, 8.0, 40, 2, 43)}
    models, fps = {}, {}
    for name, (arch, share, dlm, beta, ke, kc) in roles.items():
        cfg = reduced(ARCHS[arch], n_layers=2, d_model=192, vocab=512)
        prof = ModelProfile(name=name, beta=beta, deadline=1.0, t_edge=1.0,
                            t_cloud=1.0, cost_edge=ke, cost_cloud=kc,
                            qoe_beta=100.0, qoe_alpha=0.9,
                            qoe_window=5_000.0)
        sm = ServableModel.from_arch(prof, cfg, batch=1, seq=64)
        t95 = probe_p95(sm)
        fps[name] = min(60.0, share * 1000.0 / t95)
        prof = dataclasses.replace(prof, deadline=dlm * t95 + 30.0,
                                   t_edge=t95, t_cloud=t95 * 0.7 + 60.0)
        models[name] = dataclasses.replace(sm, profile=prof)
        print(f"{name}: p95 {t95:.1f} ms, {fps[name]:.1f} FPS, "
              f"deadline {prof.deadline:.0f} ms")
    return models, fps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="GEMS", choices=list(ALL_POLICIES))
    ap.add_argument("--duration", type=float, default=15.0)
    ap.add_argument("--cloud-concurrency", type=int, default=4)
    ap.add_argument("--backend", default="thread",
                    choices=("thread", "fleet"),
                    help="thread = Python ServeEngine with live forward "
                         "passes; fleet = compiled FleetController")
    ap.add_argument("--edges", type=int, default=2,
                    help="[fleet] number of edges in the fleet")
    ap.add_argument("--checkpoint", default=None,
                    help="[fleet] checkpoint path stem for crash restart")
    ap.add_argument("--snapshot-out", default=None,
                    help="[fleet] write the final metrics_snapshot() JSON")
    args = ap.parse_args()

    models, fps = build_roles(args.cloud_concurrency)

    if args.backend == "fleet":
        from repro.serve.controller import FleetController, drive_stream
        ctl = FleetController(
            [m.profile for m in models.values()], args.policy,
            n_edges=args.edges, cloud_slots=args.cloud_concurrency,
            checkpoint_path=args.checkpoint)
        # graceful shutdown: first SIGINT/SIGTERM stops the stream at
        # the next poll; drive_stream still flushes buffered ticks and
        # writes the final checkpoint, and the snapshot below is dumped
        # as on a normal exit.  A second signal interrupts hard.
        interrupted = []

        def _graceful(signum, frame):
            if interrupted:
                raise KeyboardInterrupt
            interrupted.append(signum)
            print(f"signal {signum}: draining — final checkpoint and "
                  f"snapshot on the way (repeat to force-quit)")

        previous = {s: signal.signal(s, _graceful)
                    for s in (signal.SIGINT, signal.SIGTERM)}
        try:
            snap = drive_stream(ctl, fps, args.duration * 1e3,
                                stop=lambda: bool(interrupted))
        finally:
            for s, h in previous.items():
                signal.signal(s, h)
        if args.snapshot_out:
            with open(args.snapshot_out, "w") as f:
                json.dump(snap, f, indent=2, default=float)
        print(json.dumps(
            {k: snap[k] for k in ("policy", "completed", "missed",
                                  "dropped", "completion_rate",
                                  "windows_run", "step_latency_ms")},
            indent=2, default=float))
        return

    engine = ServeEngine(make_policy(args.policy), models,
                         cloud_concurrency=args.cloud_concurrency)
    result = run_stream(engine, fps, args.duration * 1e3)
    print(result.summary())


if __name__ == "__main__":
    main()
