"""Serving launcher: the paper's scheduler over live model inference.

``python -m repro.launch.serve --policy GEMS --duration 15`` registers
three reduced zoo models as the Ocularone DNS (HV/DEV/BP roles), measures
their p95 latencies, and streams frame-rate tasks through the chosen
policy — the §8.8 field validation without a drone.
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.schedulers import ALL_POLICIES, make_policy
from repro.core.task import ModelProfile
from repro.serve.engine import ServableModel, ServeEngine, run_stream


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="GEMS", choices=list(ALL_POLICIES))
    ap.add_argument("--duration", type=float, default=15.0)
    ap.add_argument("--cloud-concurrency", type=int, default=4)
    args = ap.parse_args()

    roles = {"HV": ("starcoder2-3b", 0.7, 3.0, 125, 1, 25),
             "DEV": ("granite-3-2b", 0.4, 5.0, 100, 1, 26),
             "BP": ("xlstm-1.3b", 0.3, 8.0, 40, 2, 43)}
    models, fps = {}, {}
    for name, (arch, share, dlm, beta, ke, kc) in roles.items():
        cfg = reduced(ARCHS[arch], n_layers=2, d_model=192, vocab=512)
        prof = ModelProfile(name=name, beta=beta, deadline=1.0, t_edge=1.0,
                            t_cloud=1.0, cost_edge=ke, cost_cloud=kc,
                            qoe_beta=100.0, qoe_alpha=0.9,
                            qoe_window=5_000.0)
        sm = ServableModel.from_arch(prof, cfg, batch=1, seq=64)
        import time
        ts = []
        for _ in range(20):
            t0 = time.monotonic()
            sm.run()
            ts.append((time.monotonic() - t0) * 1e3)
        t95 = float(np.percentile(ts, 95))
        fps[name] = min(60.0, share * 1000.0 / t95)
        prof = dataclasses.replace(prof, deadline=dlm * t95 + 30.0,
                                   t_edge=t95, t_cloud=t95 * 0.7 + 60.0)
        models[name] = dataclasses.replace(sm, profile=prof)
        print(f"{name}: p95 {t95:.1f} ms, {fps[name]:.1f} FPS, "
              f"deadline {prof.deadline:.0f} ms")

    engine = ServeEngine(make_policy(args.policy), models,
                         cloud_concurrency=args.cloud_concurrency)
    result = run_stream(engine, fps, args.duration * 1e3)
    print(result.summary())


if __name__ == "__main__":
    main()
