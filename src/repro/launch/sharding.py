"""Logical-axis sharding with divisibility fallback.

Model code annotates tensors with *logical* axes (``"batch"``, ``"heads"``,
``"mlp"``, …).  At launch time a rule table maps logical axes to mesh axes;
``logical_to_pspec`` drops any mapping whose mesh-axis product does not
divide the tensor dimension (e.g. llava's 56 heads on a 16-way model axis),
falling back to replication for that dimension — the widest divisible axis
set wins.  Outside a rules context all annotations are no-ops, so tests and
CPU smoke runs never touch the mesh machinery.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[str, tuple[str, ...], None]

_state = threading.local()


DEFAULT_RULES: dict[str, Axes] = {
    # data-parallel axes
    "batch": ("pod", "data"),
    "fleet": ("pod", "data"),
    # tensor-parallel axes
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "ssm_inner": "model",
    # SSD/mLSTM chunk intermediates: heads (zamba: 112 % 16 = 0) or the
    # per-head dim P (xlstm: P=1024) take the model axis
    "ssm_heads": "model",
    # MoE dispatch-buffer capacity dim: data-parallel when experts cannot
    # take the model axis (grok: 8 experts < 16-way model axis)
    "moe_cap": "data",
    # MoE dispatch-group dim = data-parallel shards (group-wise dispatch):
    # all sort/scatter/gather ops stay shard-local
    "moe_grp": ("pod", "data"),
    # fallback tensor-parallel axis for big attention intermediates when
    # heads are not divisible by the model axis (llava 56H, starcoder2 24H)
    "seq_model": "model",
    # decode KV cache sequence dim: always divisible (32k / 8k windows),
    # unlike kv_heads (usually 8 < 16-way model axis) — flash-decode style
    "kv_seq": "model",
    # fsdp: parameters' embed dim sharded over the data axis
    "embed_fsdp": "data",
    # residual-stream sequence parallelism: remat-saved layer inputs are
    # (B, S, D); sharding S over 'model' cuts saved activations 16× (the
    # attention/MLP input is re-gathered per layer — Korthikanti-style SP)
    "act_seq": "model",
    # unsharded by default
    "seq": None,
    "embed": None,
    "head_dim": None,
    "state": None,
    "frames": None,
}


@contextlib.contextmanager
def sharding_rules(mesh: Mesh, rules: Optional[dict[str, Axes]] = None):
    """Activate logical-axis rules (and the mesh) for model tracing."""
    prev = getattr(_state, "ctx", None)
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop mesh axes the mesh does not actually have (e.g. "pod" on 2D)
    def filter_axes(ax: Axes) -> Axes:
        if ax is None:
            return None
        if isinstance(ax, str):
            return ax if ax in mesh.axis_names else None
        kept = tuple(a for a in ax if a in mesh.axis_names)
        return kept or None
    merged = {k: filter_axes(v) for k, v in merged.items()}
    _state.ctx = (mesh, merged)
    try:
        yield
    finally:
        _state.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def _axis_size(mesh: Mesh, ax: Axes) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax]
    size = 1
    for a in ax:
        size *= mesh.shape[a]
    return size


def logical_to_pspec(shape: Sequence[int], logical: Sequence[Optional[str]],
                     mesh: Optional[Mesh] = None,
                     rules: Optional[dict[str, Axes]] = None) -> P:
    """Resolve logical axes to a PartitionSpec, with divisibility fallback."""
    ctx = getattr(_state, "ctx", None)
    if mesh is None or rules is None:
        if ctx is None:
            return P()
        mesh = mesh or ctx[0]
        rules = rules or ctx[1]
    parts = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        ax = rules.get(name) if name else None
        size = _axis_size(mesh, ax)
        flat = (ax,) if isinstance(ax, str) else (ax or ())
        if ax is None or size == 1 or dim % size != 0 or \
                any(a in used for a in flat):
            parts.append(None)
        else:
            parts.append(ax)
            used.update(flat)
    return P(*parts)


def resolves(dim: int, logical: str) -> bool:
    """True if ``logical`` maps to mesh axes whose product divides dim
    under the active rules (False outside a rules context)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return False
    mesh, rules = ctx
    ax = rules.get(logical)
    size = _axis_size(mesh, ax)
    return size > 1 and dim % size == 0


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op outside rules)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_pspec(x.shape, logical, mesh, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def named_sharding(shape: Sequence[int], logical: Sequence[Optional[str]],
                   mesh: Mesh,
                   rules: Optional[dict[str, Axes]] = None) -> NamedSharding:
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    def filter_axes(ax: Axes) -> Axes:
        if ax is None:
            return None
        if isinstance(ax, str):
            return ax if ax in mesh.axis_names else None
        kept = tuple(a for a in ax if a in mesh.axis_names)
        return kept or None
    merged = {k: filter_axes(v) for k, v in merged.items()}
    return NamedSharding(mesh, logical_to_pspec(shape, logical, mesh, merged))
