"""Ragged grouped GEMM for MoE expert FFNs (Pallas, megablox-style).

Tokens arrive *sorted by expert* with an ``offsets`` vector (expert e owns
rows [offsets[e], offsets[e+1])).  Grid (nT, E) iterates experts innermost;
a token block multiplies only the expert weight matrices whose row range
intersects it (``pl.when`` skips the rest — for top-k routing a block spans
at most a couple of experts, so compiled work scales with tokens, not with
tokens × experts).  Fringe rows are masked elementwise.  This is the
TPU-native replacement for CUDA scatter-gather expert kernels: dispatch
order comes from a device-side sort, and the GEMM tiles stay MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 128


def _moe_kernel(off_ref, x_ref, w_ref, y_ref, acc_ref, *, block_t: int):
    i = pl.program_id(0)
    e = pl.program_id(1)
    ne = pl.num_programs(1)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    row0 = i * block_t
    lo = off_ref[e]
    hi = off_ref[e + 1]
    overlap = (lo < row0 + block_t) & (hi > row0)

    @pl.when(overlap)
    def _compute():
        x = x_ref[...].astype(jnp.float32)            # (bt, D)
        w = w_ref[0].astype(jnp.float32)              # (D, F)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_t, 1), 0)
        mask = (rows >= lo) & (rows < hi)
        y = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        acc_ref[...] += jnp.where(mask, y, 0.0)

    @pl.when(e == ne - 1)
    def _finalize():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def moe_gemm(x_sorted: jax.Array, w: jax.Array, offsets: jax.Array, *,
             block_t: int = DEFAULT_BLOCK_T,
             interpret: bool = False) -> jax.Array:
    """x_sorted: (T,D); w: (E,D,F); offsets: (E+1,) i32 → (T,F)."""
    t, d = x_sorted.shape
    e, _, f = w.shape
    block_t = min(block_t, t)
    assert t % block_t == 0
    grid = (t // block_t, e)

    kernel = functools.partial(_moe_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((e + 1,), lambda i, ee: (0,)),
            pl.BlockSpec((block_t, d), lambda i, ee: (i, 0)),
            pl.BlockSpec((1, d, f), lambda i, ee: (ee, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, f), lambda i, ee: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, f), x_sorted.dtype),
        scratch_shapes=[pltpu.VMEM((block_t, f), jnp.float32)],
        interpret=interpret,
    )(offsets, x_sorted, w)
