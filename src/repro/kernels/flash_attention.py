"""Flash attention (causal / sliding-window, GQA) as a Pallas TPU kernel.

Online-softmax tiling: grid (B, H, nQ, nK) with the K dimension innermost —
TPU grids execute sequentially, so VMEM scratch (row-max m, row-sum l,
accumulator acc) persists across K blocks of one Q block.  Block shapes are
MXU-aligned (q/k blocks of 128 × head_dim); K/V blocks for a query head are
fetched from its GQA group's KV head via the BlockSpec index map, so no
repeated-KV materialization ever reaches HBM.

Causal + sliding-window masking happens at two levels: whole K blocks
outside the band are skipped (``pl.when`` — no MXU work), and the fringe
blocks apply an elementwise mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # block-level band check: any (qpos, kpos) with kpos ≤ qpos and
    # kpos > qpos − window intersecting this block pair?
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window:
        needed = needed & (k_start + block_k - 1 > q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B,H,S,hd); k/v: (B,KV,S,hd) → (B,H,S,hd)."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    assert h % kv == 0, "GQA requires n_heads % n_kv_heads == 0"
    groups = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    grid = (b, h, s // block_q, s // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, iq, ik: (bb, hh // groups, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, iq, ik: (bb, hh // groups, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
