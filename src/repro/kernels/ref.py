"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the semantics each kernel must reproduce bit-for-bit (up to
accumulation-order tolerance).  Tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B,H,S,hd); k/v: (B,KV,S,hd) → (B,H,S,hd).  GQA via repeat."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    groups = h // kv
    k = jnp.repeat(k, groups, axis=1)
    v = jnp.repeat(v, groups, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    logits *= hd ** -0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def ref_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B,H,hd); k/v: (B,KV,W,hd); lengths: (B,) valid prefix → (B,H,hd)."""
    b, h, hd = q.shape
    kv = k.shape[1]
    groups = h // kv
    k = jnp.repeat(k, groups, axis=1)
    v = jnp.repeat(v, groups, axis=1)
    logits = jnp.einsum("bhd,bhkd->bhk", q, k).astype(jnp.float32)
    logits *= hd ** -0.5
    valid = jnp.arange(k.shape[2])[None, :] < lengths[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhk,bhkd->bhd", probs, v)


def ref_selective_scan(x: jax.Array, dt: jax.Array, a: jax.Array,
                       bmat: jax.Array, cmat: jax.Array):
    """Sequential SSD recurrence (the Mamba2 core).

    x: (G,S,P); dt: (G,S); a: (G,); bmat/cmat: (G,S,N).
      state_t = exp(a·dt_t)·state_{t−1} + dt_t·(x_t ⊗ B_t)
      y_t     = state_t · C_t
    Returns (y (G,S,P), final_state (G,P,N)).  G = batch×heads.
    """
    def per_g(xg, dtg, ag, bg, cg):
        def step(state, inp):
            xt, dtt, bt, ct = inp
            dec = jnp.exp(ag * dtt)
            state = state * dec + dtt * jnp.outer(xt, bt)
            return state, state @ ct
        init = jnp.zeros((x.shape[-1], bg.shape[-1]), jnp.float32)
        final, ys = jax.lax.scan(
            step, init, (xg.astype(jnp.float32), dtg.astype(jnp.float32),
                         bg.astype(jnp.float32), cg.astype(jnp.float32)))
        return ys, final
    y, fin = jax.vmap(per_g)(x, dt, a, bmat, cmat)
    return y.astype(x.dtype), fin.astype(x.dtype)


def ref_moe_gemm(x_sorted: jax.Array, w: jax.Array,
                 offsets: jax.Array) -> jax.Array:
    """Ragged grouped GEMM oracle.

    x_sorted: (T,D) tokens sorted by expert; w: (E,D,F);
    offsets: (E+1,) — expert e owns rows [offsets[e], offsets[e+1]).
    """
    t = x_sorted.shape[0]
    e = w.shape[0]
    rows = jnp.arange(t)
    expert_of = jnp.sum(rows[:, None] >= offsets[None, 1:], axis=1)
    expert_of = jnp.clip(expert_of, 0, e - 1)
    return jnp.einsum("td,tdf->tf", x_sorted, w[expert_of])


def ref_masked_argext(scores: jax.Array, mask: jax.Array, *,
                      is_max: bool) -> tuple[jax.Array, jax.Array]:
    """Masked first-occurrence arg-extremum over the last axis.

    The scheduler-selection contract of ``kernels.sched_ops``: disabled
    entries are filled with ∓1e30, ``idx`` is the first index attaining
    the extremum (``jnp.argmax``/``argmin`` tie-breaking), and a row with
    no enabled entry yields ``idx == -1`` with the fill value.
    """
    fill = -1e30 if is_max else 1e30
    v = jnp.where(mask, scores.astype(jnp.float32), fill)
    idx = (jnp.argmax(v, -1) if is_max else jnp.argmin(v, -1)).astype(
        jnp.int32)
    some = jnp.broadcast_to(mask, v.shape).any(-1)
    val = v.max(-1) if is_max else v.min(-1)
    return jnp.where(some, idx, -1), val


def ref_rmsnorm(x: jax.Array, scale: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    return ((x.astype(jnp.float32) * jax.lax.rsqrt(var + eps))
            * scale.astype(jnp.float32)).astype(x.dtype)
