"""Selective state-space scan (Mamba2/SSD core) as a Pallas TPU kernel.

The recurrence  state_t = exp(a·dt_t)·state_{t−1} + dt_t·(x_t ⊗ B_t),
y_t = state_t·C_t  is sharded over (batch × heads) on the first grid axis
and *chunked* over time on the second (sequential) axis; the (P, N) state
matrix lives in VMEM scratch and persists across chunks — the TPU analogue
of Mamba's SRAM-resident selective scan.  Within a chunk the step loop is a
``fori_loop`` over rank-1 updates, keeping the full (P, N) state in
registers/VMEM rather than round-tripping HBM per token.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fin_ref,
                state_ref, *, chunk: int):
    j = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0]

    def step(t, state):
        xt = x_ref[0, t].astype(jnp.float32)          # (P,)
        bt = b_ref[0, t].astype(jnp.float32)          # (N,)
        ct = c_ref[0, t].astype(jnp.float32)          # (N,)
        dtt = dt_ref[0, t].astype(jnp.float32)
        dec = jnp.exp(a * dtt)
        state = state * dec + dtt * (xt[:, None] * bt[None, :])
        y_ref[0, t, :] = (state @ ct).astype(y_ref.dtype)
        return state

    state = jax.lax.fori_loop(0, chunk, step, state_ref[...])
    state_ref[...] = state

    @pl.when(j == nc - 1)
    def _finalize():
        fin_ref[0] = state.astype(fin_ref.dtype)


def ssm_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
             cmat: jax.Array, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = False):
    """x: (G,S,P); dt: (G,S); a: (G,); bmat/cmat: (G,S,N).

    Returns (y (G,S,P), final_state (G,P,N)).  G = batch × heads.
    """
    g, s, p = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    grid = (g, s // chunk)

    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, p, n), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, s, p), x.dtype),
            jax.ShapeDtypeStruct((g, p, n), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bmat, cmat)
