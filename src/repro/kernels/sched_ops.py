"""Masked segmented argmin/argmax scoring as a Pallas TPU kernel.

Every selection the fleet scheduler makes per tick is the same reduction:
score a masked set of candidates and take the first extremum — stealing a
cloud-queued task (§5.3), picking a peer-offload export victim, choosing
the overloaded source edge and least-loaded destination edge.  On TPU the
whole fleet's selections run as one VPU pass over a ``(batch, N)`` score
tile; each row yields the first-occurrence arg-extremum and its value.

Semantics (shared bit-for-bit with :func:`repro.kernels.ref.
ref_masked_argext`, the jnp oracle):

* masked-out entries count as ``NEG`` (max mode) / ``POS`` (min mode);
* ``idx`` is the *first* index attaining the extremum (ties break low,
  matching ``jnp.argmax``/``jnp.argmin`` on the filled array);
* a row with no enabled entry returns ``idx == -1`` and the fill value.

On CPU (this container) the public wrappers trace the jnp reference —
identical semantics, no interpret-mode overhead in the per-tick hot path;
``interpret=True`` forces the actual kernel body through the Pallas
interpreter for equivalence tests.  On TPU they compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

NEG = -1e30
POS = 1e30

DEFAULT_BLOCK_B = 8
_LANES = 128


def _argext_kernel(s_ref, m_ref, idx_ref, val_ref, *, is_max: bool,
                   n: int):
    """One (block_b, Np) tile → per-row (first arg-extremum, value)."""
    fill = NEG if is_max else POS
    s = s_ref[...].astype(jnp.float32)                       # (bb, Np)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    enabled = m_ref[...] & (cols < n)                        # lane padding
    v = jnp.where(enabled, s, fill)
    best = v.max(axis=-1) if is_max else v.min(axis=-1)
    hit = v == best[:, None]
    first = jnp.where(hit, cols, n).min(axis=-1)
    idx_ref[...] = jnp.where(enabled.any(axis=-1), first, -1)
    val_ref[...] = best


def _pallas_argext(scores: jax.Array, mask: jax.Array, *, is_max: bool,
                   block_b: int, interpret: bool):
    b, n = scores.shape
    block_b = min(block_b, b)
    pad_b = (-b) % block_b
    pad_n = (-n) % _LANES
    s = jnp.pad(scores.astype(jnp.float32), ((0, pad_b), (0, pad_n)))
    m = jnp.pad(mask, ((0, pad_b), (0, pad_n)))
    np_ = n + pad_n
    grid = (s.shape[0] // block_b,)
    idx, val = pl.pallas_call(
        functools.partial(_argext_kernel, is_max=is_max, n=n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, np_), lambda i: (i, 0)),
                  pl.BlockSpec((block_b, np_), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_b,), lambda i: (i,)),
                   pl.BlockSpec((block_b,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((s.shape[0],), jnp.int32),
                   jax.ShapeDtypeStruct((s.shape[0],), jnp.float32)],
        interpret=interpret,
    )(s, m)
    return idx[:b], val[:b]


def masked_argext(scores: jax.Array, mask: jax.Array, *, is_max: bool,
                  block_b: int = DEFAULT_BLOCK_B,
                  interpret: Optional[bool] = None):
    """``scores, mask: (..., N)`` → ``(idx (...,), val (...,))``.

    ``interpret=None`` resolves the backend once: the Pallas kernel on
    TPU, the jnp reference on anything else (so vmapped/scanned hot-path
    callers never hit the Python interpreter).  ``interpret=True`` runs
    the kernel body through the Pallas interpreter regardless — the
    kernel-vs-reference test path.
    """
    if interpret is None:
        if jax.default_backend() != "tpu":
            return ref.ref_masked_argext(scores, mask, is_max=is_max)
        interpret = False
    lead = scores.shape[:-1]
    n = scores.shape[-1]
    s2 = scores.reshape(-1, n)
    m2 = jnp.broadcast_to(mask, scores.shape).reshape(-1, n)
    idx, val = _pallas_argext(s2, m2, is_max=is_max, block_b=block_b,
                              interpret=interpret)
    return idx.reshape(lead), val.reshape(lead)


def masked_argmax(scores, mask, **kw):
    """First argmax over enabled entries; (-1, NEG) when none enabled."""
    return masked_argext(scores, mask, is_max=True, **kw)


def masked_argmin(scores, mask, **kw):
    """First argmin over enabled entries; (-1, POS) when none enabled."""
    return masked_argext(scores, mask, is_max=False, **kw)
