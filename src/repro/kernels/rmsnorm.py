"""Fused RMSNorm as a Pallas TPU kernel.

RMSNorm is issued 2–3× per layer on the (B, S, D) residual stream — pure
memory traffic.  Unfused, XLA reads x for the mean-square reduction and
again for the scale-multiply; the fused kernel streams each (block, D) row
tile through VMEM once, computing the fp32 reduction and the normalized
output in registers.  Grid (rows/block,) with full-D tiles (D ≤ a few
thousand fits VMEM comfortably at block 128 rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_R = 128


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (br, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
            block_r: int = DEFAULT_BLOCK_R,
            interpret: bool = False) -> jax.Array:
    """x: (..., D); scale: (D,) → same shape as x."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_r = min(block_r, rows)
    pad = (-rows) % block_r
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_r,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        scratch_shapes=[],
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
