"""Flash-decode: single-token attention over a long KV cache (Pallas).

Serving's decode step attends one query token against up to 500k cached
keys — memory-bandwidth-bound, so the kernel streams the cache through VMEM
in blocks with an online-softmax accumulator, never materializing the
(H, S) logits row in HBM.  Grid (B, H, nS) with the cache-block dimension
innermost (sequential → scratch carries m/l/acc).  Valid-length masking
(cache slots beyond the write position) comes from a per-batch ``lengths``
vector.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 256
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_s: int):
    ib = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    k_start = ik * block_s

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (hd,)
        k = k_ref[0, 0].astype(jnp.float32)           # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = (k @ q) * scale                           # (bs,)
        idx = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_s,), 0)
        s = jnp.where(idx < length, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, s.max())
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(idx < length, jnp.exp(s - m_new), 0.0)
        l_ref[0] = l_ref[0] * alpha + p.sum()
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[0] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *,
                     block_s: int = DEFAULT_BLOCK_S,
                     interpret: bool = False) -> jax.Array:
    """q: (B,H,hd); k/v: (B,KV,W,hd); lengths: (B,) → (B,H,hd)."""
    b, h, hd = q.shape
    kv, w = k.shape[1], k.shape[2]
    groups = h // kv
    block_s = min(block_s, w)
    assert w % block_s == 0
    grid = (b, h, w // block_s)

    kernel = functools.partial(_decode_kernel, scale=hd ** -0.5,
                               block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, hh, ik: (bb,)),
            pl.BlockSpec((1, 1, hd), lambda bb, hh, ik: (bb, hh, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda bb, hh, ik: (bb, hh // groups, ik, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda bb, hh, ik: (bb, hh // groups, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda bb, hh, ik: (bb, hh, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((hd,), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
