"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU they compile to
Mosaic.  ``interpret`` is resolved once from the default backend and can be
overridden per call.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.ssm_scan import ssm_scan as _ssm
from repro.kernels.moe_gemm import moe_gemm as _moe
from repro.kernels.rmsnorm import rmsnorm as _rms


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q: (B,H,S,hd); k/v: (B,KV,S,hd) → (B,H,S,hd)."""
    itp = _interpret_default() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=itp)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k, v, lengths, *, block_s: int = 256,
                     interpret: Optional[bool] = None):
    """q: (B,H,hd); k/v: (B,KV,W,hd); lengths: (B,) → (B,H,hd)."""
    itp = _interpret_default() if interpret is None else interpret
    return _decode(q, k, v, lengths.astype(jnp.int32), block_s=block_s,
                   interpret=itp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(x, dt, a, bmat, cmat, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """Selective scan: see kernels.ssm_scan."""
    itp = _interpret_default() if interpret is None else interpret
    return _ssm(x, dt, a, bmat, cmat, chunk=chunk, interpret=itp)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def moe_gemm(x_sorted, w, offsets, *, block_t: int = 128,
             interpret: Optional[bool] = None):
    """Ragged grouped GEMM: see kernels.moe_gemm."""
    itp = _interpret_default() if interpret is None else interpret
    return _moe(x_sorted, w, offsets.astype(jnp.int32), block_t=block_t,
                interpret=itp)


@functools.partial(jax.jit, static_argnames=("eps", "block_r", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_r: int = 128,
            interpret: Optional[bool] = None):
    """Fused RMSNorm: see kernels.rmsnorm."""
    itp = _interpret_default() if interpret is None else interpret
    return _rms(x, scale, eps=eps, block_r=block_r, interpret=itp)
