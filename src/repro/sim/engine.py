"""Discrete-event simulator of one edge base station + cloud FaaS (§3.3).

Faithfully models the paper's runtime architecture:

* a **task scheduler** routing each arriving task to the edge queue, the
  cloud queue, or dropping it (policy-driven, §5–6);
* an **edge executor**: synchronous, single-stream (Jetson-class GPUs have
  no concurrent kernel execution), JIT deadline check before execution;
* a **cloud executor**: a thread pool of ``cloud_concurrency`` slots over a
  trigger-time priority queue (FIFO ≙ trigger=now for baselines), JIT check
  at dispatch;
* a **window monitor** maintaining per-model tumbling windows for the QoE
  metric and driving the GEMS rescheduler (Alg. 1).

Time unit: milliseconds.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np

from repro.core.schedulers import AdaptiveEstimator, Policy
from repro.core.task import ModelProfile, Outcome, Task
from repro.sim.network import CloudLatencyModel, EdgeLatencyModel


@dataclasses.dataclass
class Arrival:
    time: float
    model: ModelProfile
    drone: int = 0


@dataclasses.dataclass
class ModelStats:
    generated: int = 0
    edge_success: int = 0
    cloud_success: int = 0
    edge_miss: int = 0
    cloud_miss: int = 0
    dropped: int = 0
    stolen: int = 0
    migrated: int = 0
    gems_rescheduled: int = 0
    qos_utility: float = 0.0
    edge_utility: float = 0.0
    cloud_utility: float = 0.0
    qoe_utility: float = 0.0
    windows_met: int = 0
    windows_total: int = 0

    @property
    def completed(self) -> int:
        return self.edge_success + self.cloud_success


@dataclasses.dataclass
class Results:
    policy: str
    duration: float
    per_model: dict[str, ModelStats]
    edge_busy: float = 0.0

    def _sum(self, attr: str) -> float:
        return sum(getattr(s, attr) for s in self.per_model.values())

    @property
    def generated(self) -> int: return int(self._sum("generated"))
    @property
    def completed(self) -> int: return int(self._sum("completed"))
    @property
    def completion_rate(self) -> float:
        return self.completed / max(self.generated, 1)
    @property
    def qos_utility(self) -> float: return self._sum("qos_utility")
    @property
    def edge_utility(self) -> float: return self._sum("edge_utility")
    @property
    def cloud_utility(self) -> float: return self._sum("cloud_utility")
    @property
    def qoe_utility(self) -> float: return self._sum("qoe_utility")
    @property
    def total_utility(self) -> float:
        return self.qos_utility + self.qoe_utility
    @property
    def stolen(self) -> int: return int(self._sum("stolen"))
    @property
    def migrated(self) -> int: return int(self._sum("migrated"))
    @property
    def gems_rescheduled(self) -> int: return int(self._sum("gems_rescheduled"))
    @property
    def edge_utilization(self) -> float:
        return self.edge_busy / max(self.duration, 1e-9)

    def summary(self) -> str:
        return (f"{self.policy:8s} tasks={self.completed}/{self.generated} "
                f"({100 * self.completion_rate:.1f}%) QoS={self.qos_utility:.0f} "
                f"QoE={self.qoe_utility:.0f} total={self.total_utility:.0f} "
                f"edge_util={100 * self.edge_utilization:.0f}% "
                f"stolen={self.stolen} migrated={self.migrated} "
                f"gems={self.gems_rescheduled}")


class _WindowState:
    """Per-model tumbling-window QoE accounting (Eqn 2 / Alg. 1 state)."""

    __slots__ = ("end", "width", "lam", "lam_hat", "prev_lam")

    def __init__(self, width: float):
        self.end = width
        self.width = width
        self.lam = 0
        self.lam_hat = 0
        self.prev_lam = 0     # arrivals seen in the previous window

    @property
    def rate(self) -> float:
        return self.lam_hat / self.lam if self.lam else 1.0

    def winnable(self, alpha: float, now: float) -> bool:
        """GEMS-B: can α̂ still reach α if every remaining task in this
        window succeeds?  Remaining count is estimated from the previous
        window's arrivals, prorated by the time left."""
        frac_left = max(0.0, (self.end - now) / self.width)
        remaining = max(self.prev_lam, self.lam) * frac_left
        return (self.lam_hat + remaining) >= alpha * (self.lam + remaining) \
            - 1e-9


class Simulator:
    """One edge base station and its share of the cloud FaaS."""

    def __init__(self, policy: Policy, arrivals: list[Arrival],
                 duration: float, *,
                 cloud_concurrency: int = 16,
                 edge_model: Optional[EdgeLatencyModel] = None,
                 cloud_model: Optional[CloudLatencyModel] = None,
                 cloud_outages: tuple[tuple[float, float], ...] = (),
                 outage_cold_ms: float = 0.0,
                 outage_cold_window_ms: float = 3_000.0,
                 edge_down_windows: tuple[tuple[float, float], ...] = (),
                 cloud_give_up_ms: float = float("inf"),
                 seed: int = 0):
        self.policy = policy
        self.arrivals = sorted(arrivals, key=lambda a: a.time)
        self.duration = duration
        self.rng = np.random.default_rng(seed)
        self.edge_model = edge_model or EdgeLatencyModel()
        self.cloud_model = cloud_model or CloudLatencyModel()
        self.cloud_slots = cloud_concurrency
        # cloud FaaS outage windows (scenario events): dispatch stalls
        # during [start, end); dispatches shortly after recovery pay a
        # cold-start penalty (the warm container pool has drained).
        # Entries are (start, end) or (start, end, cold_ms, cold_window_ms);
        # 2-tuples take the Simulator-level defaults.
        self.cloud_outages = tuple(sorted(
            tuple(o) if len(tuple(o)) == 4
            else (*o, outage_cold_ms, outage_cold_window_ms)
            for o in cloud_outages))
        self._recovery_checks: set[float] = set()
        # chaos-engine fault hooks: edge scheduler crash windows (queued
        # work flushed at the start, nothing admitted until the end; the
        # in-flight kernel completes — a scheduler crash, not a power
        # cut) and the bounded cloud-dispatch patience, matching the
        # fleet simulator's ``cloud_give_up_ms`` drop lane
        self.edge_down_windows = tuple(sorted(
            (float(s), float(e)) for s, e in edge_down_windows))
        self.cloud_give_up = cloud_give_up_ms
        self.edge_down = False

        self.profiles: dict[str, ModelProfile] = {}
        for a in self.arrivals:
            self.profiles.setdefault(a.model.name, a.model)
        self.min_edge_t = min((m.t_edge for m in self.profiles.values()),
                              default=0.0)

        # runtime state -------------------------------------------------
        self._heap: list[tuple[float, int, str, object]] = []
        self._seq = 0
        self.now = 0.0
        self.edge_queue: list[Task] = []       # sorted by policy.edge_key
        self.edge_current: Optional[Task] = None
        self.edge_busy_until = 0.0
        self.edge_busy_total = 0.0
        self.cloud_pending: list[Task] = []    # sorted by trigger time
        self.cloud_inflight = 0
        self._triggers: dict[int, float] = {}  # task uid -> trigger time
        self.adaptive: dict[str, AdaptiveEstimator] = {
            n: AdaptiveEstimator(static=m.t_cloud)
            for n, m in self.profiles.items()}
        self.windows: dict[str, _WindowState] = {
            n: _WindowState(m.qoe_window) for n, m in self.profiles.items()
            if m.qoe_alpha > 0}
        self.stats = {n: ModelStats() for n in self.profiles}
        self.tasks: list[Task] = []
        self._uid = 0

    # ------------------------------------------------------------------
    # event plumbing
    # ------------------------------------------------------------------
    def _push(self, time: float, kind: str, data: object = None) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (time, self._seq, kind, data))

    def _t_cloud(self, m: ModelProfile) -> float:
        """Scheduler's current cloud-latency estimate for ``m`` (§5.4)."""
        if self.policy.adaptive:
            return self.adaptive[m.name].current
        return m.t_cloud

    # ------------------------------------------------------------------
    # edge queue helpers
    # ------------------------------------------------------------------
    def _edge_start_time(self) -> float:
        return max(self.edge_busy_until, self.now)

    def _insert_pos(self, task: Task) -> int:
        key = self.policy.edge_key(task)
        lo = 0
        for i, t in enumerate(self.edge_queue):
            if self.policy.edge_key(t) <= key:
                lo = i + 1
        return lo

    def _projected(self, queue: list[Task]) -> list[float]:
        """Projected completion time of each queued task (§5.2)."""
        cur = self._edge_start_time()
        out = []
        for t in queue:
            cur += t.model.t_edge
            out.append(cur)
        return out

    def _feasible_at(self, queue: list[Task], pos: int, task: Task) -> bool:
        wait = self._edge_start_time() + sum(
            t.model.t_edge for t in queue[:pos])
        return wait + task.model.t_edge <= task.sched_deadline

    def _victims_of_insert(self, pos: int, task: Task) -> list[Task]:
        """Existing tasks newly pushed past their deadline by the insert."""
        before = self._projected(self.edge_queue)
        shifted = task.model.t_edge
        victims = []
        for i in range(pos, len(self.edge_queue)):
            t = self.edge_queue[i]
            if before[i] <= t.sched_deadline < before[i] + shifted:
                victims.append(t)
        return victims

    # ------------------------------------------------------------------
    # routing (task scheduler thread, §3.3)
    # ------------------------------------------------------------------
    def _route(self, task: Task) -> None:
        p = self.policy
        if self.edge_down:
            # crashed edge admits nothing: arrivals re-route cloud-ward
            # (mirroring the fleet's ``insert_edge &= edge_up`` gate)
            self._offer_cloud(task) or self._drop(task)
            return
        if not p.use_edge:
            self._offer_cloud(task) or self._drop(task)
            return
        if not p.use_cloud and not p.edge_feasibility_check:
            self._edge_insert(task, self._insert_pos(task))   # edge-only
            return
        if p.sota1:
            self._route_sota1(task)
            return
        if p.sota2:
            self._route_sota2(task)
            return

        pos = self._insert_pos(task)
        if self._feasible_at(self.edge_queue, pos, task):
            if p.migration:
                victims = self._victims_of_insert(pos, task)
                if victims and not p.migration_decision(
                        task, victims, self.now,
                        lambda m: self._t_cloud(m)):
                    self._offer_cloud(task) or self._drop(task)
                    return
                for v in victims:
                    self.edge_queue.remove(v)
                    v.migrated = True
                    self.stats[v.model.name].migrated += 1
                    self._offer_cloud(v) or self._drop(v)
                self._edge_insert(task, self._insert_pos(task))
            else:
                self._edge_insert(task, pos)
        else:
            self._offer_cloud(task) or self._drop(task)

    def _route_sota1(self, task: Task) -> None:
        """Kalmia+D3 adaptation: urgent/non-urgent, 10 % deadline buffer."""
        pos = self._insert_pos(task)
        if self._feasible_at(self.edge_queue, pos, task):
            self._edge_insert(task, pos)
            return
        urgent = task.model.deadline <= self.policy.urgent_deadline
        if not urgent:
            task.deadline_ext = 0.1 * task.model.deadline
            pos = self._insert_pos(task)
            if self._feasible_at(self.edge_queue, pos, task):
                self._edge_insert(task, pos)
                return
        self._offer_cloud(task) or self._drop(task)

    def _route_sota2(self, task: Task) -> None:
        """Dedas adaptation: exec-time priority + average-completion-time.

        Victim count >1 → cloud.  Exactly one violation → keep the schedule
        whose mean completion time (ACT) over all queued tasks is lower;
        inserting nearly always raises ACT, so such tasks go to the cloud —
        matching the paper's observation that SOTA2 leans on the cloud.
        """
        pos = self._insert_pos(task)
        own_ok = self._feasible_at(self.edge_queue, pos, task)
        victims = self._victims_of_insert(pos, task)
        nviol = len(victims) + (0 if own_ok else 1)
        if nviol == 0:
            self._edge_insert(task, pos)
            return
        if nviol > 1:
            self._offer_cloud(task) or self._drop(task)
            return
        before = self._projected(self.edge_queue)
        after_q = self.edge_queue[:pos] + [task] + self.edge_queue[pos:]
        after = self._projected(after_q)
        act_before = sum(before) / len(before) if before else float("inf")
        act_after = sum(after) / len(after)
        if own_ok and act_after <= act_before:
            self._edge_insert(task, pos)
        else:
            self._offer_cloud(task) or self._drop(task)

    # ------------------------------------------------------------------
    # edge executor
    # ------------------------------------------------------------------
    def _edge_insert(self, task: Task, pos: int) -> None:
        self.edge_queue.insert(pos, task)
        self._edge_dispatch()

    def _edge_dispatch(self) -> None:
        if self.edge_current is not None or self.edge_down:
            return
        # JIT check: drop heads that can no longer meet their deadline.
        while self.edge_queue:
            head = self.edge_queue[0]
            if self.now + head.model.t_edge > head.sched_deadline:
                self._drop(self.edge_queue.pop(0))
            else:
                break
        task = self._try_steal() if self.policy.stealing else None
        if task is None:
            if not self.edge_queue:
                return
            task = self.edge_queue.pop(0)
        dur = self.edge_model.sample(self.rng, task.model.t_edge,
                                     now=self.now, model=task.model.name)
        self.edge_current = task
        self.edge_busy_until = self.now + dur
        self.edge_busy_total += dur
        self._push(self.now + dur, "edge_done", task)

    def _try_steal(self) -> Optional[Task]:
        """Work stealing from the cloud queue into edge slack (§5.3)."""
        if self.edge_queue:
            head = self.edge_queue[0]
            slack = head.abs_deadline - (self.now + head.model.t_edge)
            if slack <= self.min_edge_t:
                return None
            proj = self._projected(self.edge_queue)
            max_delay = min(t.sched_deadline - c
                            for t, c in zip(self.edge_queue, proj))
            if max_delay <= 0:
                return None
        else:
            max_delay = float("inf")
        eligible = [c for c in self.cloud_pending
                    if c.model.t_edge <= max_delay
                    and self.now + c.model.t_edge <= c.abs_deadline]
        if not eligible:
            return None
        # negative-cloud-utility (steal-only) tasks first, then rank.
        eligible.sort(key=lambda c: (not c.steal_only,
                                     -c.model.steal_rank()))
        task = eligible[0]
        self.cloud_pending.remove(task)
        task.stolen = True
        self.stats[task.model.name].stolen += 1
        return task

    # ------------------------------------------------------------------
    # cloud executor (FaaS thread pool + trigger-time queue)
    # ------------------------------------------------------------------
    def _offer_cloud(self, task: Task) -> bool:
        acc = self.policy.offer_cloud(task, self.now,
                                      self._t_cloud(task.model))
        if not acc.accept:
            if self.policy.adaptive and self.policy.use_cloud:
                self.adaptive[task.model.name].on_skip(self.now)
            return False
        task.steal_only = acc.steal_only
        self._triggers[task.uid] = acc.trigger
        i = 0
        while i < len(self.cloud_pending) and \
                self._triggers[self.cloud_pending[i].uid] <= acc.trigger:
            i += 1
        self.cloud_pending.insert(i, task)
        if acc.trigger <= self.now:
            self._cloud_dispatch()
        else:
            self._push(acc.trigger, "cloud_check", None)
        if not acc.steal_only and self.cloud_give_up != float("inf"):
            # guarantee a dispatch sweep right past the give-up horizon
            # even if no other event lands there (e.g. mid-outage)
            self._push(acc.trigger + self.cloud_give_up + 1e-6,
                       "cloud_check", None)
        return True

    def _outage_end(self, t: float) -> Optional[float]:
        """End of the outage window containing ``t``, or None if cloud up."""
        for start, end, _, _ in self.cloud_outages:
            if start <= t < end:
                return end
        return None

    def _cold_penalty(self) -> float:
        """Post-outage cold start: warm pool drained while the cloud was
        down, so dispatches within that outage's cold window pay its
        warmup price."""
        for _, end, cold_ms, cold_window_ms in self.cloud_outages:
            if cold_ms and 0.0 <= self.now - end < cold_window_ms:
                return cold_ms
        return 0.0

    def _cloud_dispatch(self) -> None:
        if self.cloud_give_up != float("inf"):
            # bounded patience: parked dispatches past the give-up
            # horizon are abandoned (steal-only parks keep their own
            # expiry path).  Remove before dropping — a drop can trigger
            # a GEMS rescan that re-enters this queue.
            expired = [t for t in self.cloud_pending
                       if not t.steal_only
                       and self.now - self._triggers[t.uid]
                       > self.cloud_give_up]
            for t in expired:
                self.cloud_pending.remove(t)
            for t in expired:
                self._drop(t)
        up_at = self._outage_end(self.now)
        if up_at is not None:
            # cloud down: park everything; re-check the queue on recovery.
            if up_at not in self._recovery_checks:
                self._recovery_checks.add(up_at)
                self._push(up_at, "cloud_check", None)
            return
        while self.cloud_inflight < self.cloud_slots and self.cloud_pending:
            task = self.cloud_pending[0]
            if self._triggers[task.uid] > self.now:
                break
            self.cloud_pending.pop(0)
            if task.steal_only:
                self._drop(task)            # not stolen in time → JIT drop
                continue
            est = self._t_cloud(task.model)
            if self.now + est > task.abs_deadline:
                self._drop(task)            # JIT deadline check
                if self.policy.adaptive:
                    self.adaptive[task.model.name].on_skip(self.now)
                continue
            if self.policy.adaptive:
                self.adaptive[task.model.name].on_sent()
            dur = self.cloud_model.sample(
                self.rng, task.model.t_cloud, self.now,
                model=task.model.name) + self._cold_penalty()
            self.cloud_inflight += 1
            self._push(self.now + dur, "cloud_done", (task, dur))

    # ------------------------------------------------------------------
    # completion, drops, QoE windows (window-monitor thread + Alg. 1)
    # ------------------------------------------------------------------
    def _drop(self, task: Task) -> bool:
        task.outcome = Outcome.DROPPED
        task.finished = self.now
        self.stats[task.model.name].dropped += 1
        self._window_update(task, success=False)
        return True

    def _finish(self, task: Task, where: str) -> None:
        task.finished = self.now
        ok = self.now <= task.abs_deadline
        st = self.stats[task.model.name]
        if where == "edge":
            task.outcome = Outcome.EDGE_SUCCESS if ok else Outcome.EDGE_MISS
            st.edge_success += ok
            st.edge_miss += (not ok)
            st.edge_utility += task.utility()
        else:
            task.outcome = Outcome.CLOUD_SUCCESS if ok else Outcome.CLOUD_MISS
            st.cloud_success += ok
            st.cloud_miss += (not ok)
            st.cloud_utility += task.utility()
        st.qos_utility += task.utility()
        self._window_update(task, success=ok)

    def _window_update(self, task: Task, success: bool) -> None:
        wm = self.windows.get(task.model.name)
        if wm is None:
            return
        self._close_windows(task.model, until=self.now)
        wm.lam += 1
        wm.lam_hat += success
        if self.policy.gems and wm.rate < task.model.qoe_alpha:
            lost = self.policy.gems_budget and not wm.winnable(
                task.model.qoe_alpha, self.now)
            # GEMS-B: once the window is mathematically lost, stop the
            # Alg-1 flood; only salvage tasks already doomed on the edge
            # (pure QoS rescue — no QoE can be recovered this window)
            self._gems_rescan(task.model, only_doomed=lost)

    def _close_windows(self, m: ModelProfile, until: float) -> None:
        wm = self.windows[m.name]
        st = self.stats[m.name]
        while until > wm.end:
            if wm.lam > 0:
                st.windows_total += 1
                if wm.rate >= m.qoe_alpha:
                    st.windows_met += 1
                    st.qoe_utility += m.qoe_beta
            wm.prev_lam = wm.lam
            wm.lam = wm.lam_hat = 0
            wm.end += wm.width

    def _gems_rescan(self, m: ModelProfile,
                     only_doomed: bool = False) -> None:
        """Alg. 1 lines 9–14: push lagging model's edge tasks to the cloud.

        ``only_doomed`` (GEMS-B) restricts the move to tasks whose
        projected *edge* completion already misses their deadline.
        """
        if m.gamma_cloud <= 0:
            return
        est = self._t_cloud(m)
        if only_doomed:
            proj = self._projected(self.edge_queue)
            doomed = {t.uid for t, c in zip(self.edge_queue, proj)
                      if c > t.sched_deadline}
        moved = [t for t in self.edge_queue
                 if t.model.name == m.name
                 and self.now + est <= t.abs_deadline
                 and (not only_doomed or t.uid in doomed)]
        for t in moved:
            self.edge_queue.remove(t)
            t.gems_rescheduled = True
            self.stats[m.name].gems_rescheduled += 1
            self._triggers[t.uid] = self.now
            self.cloud_pending.insert(
                self._bisect_trigger(self.now), t)
        if moved:
            self._cloud_dispatch()

    def _bisect_trigger(self, trig: float) -> int:
        i = 0
        while i < len(self.cloud_pending) and \
                self._triggers[self.cloud_pending[i].uid] <= trig:
            i += 1
        return i

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------
    def prime(self) -> None:
        """Push every arrival onto the event heap (call exactly once)."""
        for a in self.arrivals:
            self._push(a.time, "arrival", a)
        for start, end in self.edge_down_windows:
            self._push(start, "edge_crash", None)
            self._push(end, "edge_restart", None)

    def _handle(self, time: float, kind: str, data: object) -> None:
        self.now = time
        if kind == "arrival":
            a: Arrival = data  # type: ignore[assignment]
            self._uid += 1
            task = Task(uid=self._uid, model=a.model,
                        created=a.time, drone=a.drone)
            self.tasks.append(task)
            self.stats[a.model.name].generated += 1
            self._route(task)
        elif kind == "edge_done":
            task = data  # type: ignore[assignment]
            self.edge_current = None
            self._finish(task, "edge")
            self._edge_dispatch()
        elif kind == "cloud_done":
            task, dur = data  # type: ignore[misc]
            self.cloud_inflight -= 1
            if self.policy.adaptive:
                self.adaptive[task.model.name].observe(dur)
            self._finish(task, "cloud")
            self._cloud_dispatch()
        elif kind == "cloud_check":
            self._cloud_dispatch()
        elif kind == "edge_crash":
            # scheduler crash: every queued task is lost at once (clear
            # first — dropping can fire a GEMS rescan over the queue),
            # the in-flight kernel still completes, nothing is admitted
            # until restart
            self.edge_down = True
            flushed = self.edge_queue
            self.edge_queue = []
            for t in flushed:
                self._drop(t)
        elif kind == "edge_restart":
            self.edge_down = False
            self._edge_dispatch()

    def run_until(self, t: float) -> None:
        """Drain events up to and including time ``t`` (lockstep slices:
        the multi-edge :class:`FleetOracle` interleaves these with
        cross-edge exchanges)."""
        while self._heap and self._heap[0][0] <= t:
            time, _, kind, data = heapq.heappop(self._heap)
            self._handle(time, kind, data)

    def finalize(self) -> Results:
        self.now = self.duration
        for name, wm in self.windows.items():
            self._close_windows(self.profiles[name], until=self.duration + 1)
        return Results(policy=self.policy.name, duration=self.duration,
                       per_model=self.stats, edge_busy=self.edge_busy_total)

    def run(self) -> Results:
        self.prime()
        self.run_until(float("inf"))
        return self.finalize()


def run_policy(policy: Policy, arrivals: list[Arrival], duration: float,
               **kw) -> Results:
    return Simulator(policy, arrivals, duration, **kw).run()


class FleetOracle:
    """Multi-edge oracle: per-edge :class:`Simulator`\\ s in lockstep.

    Runs every edge's event heap in ``dt`` slices and, between slices,
    exchanges tasks across edges exactly like the fleet simulator's
    :func:`repro.sim.fleet_jax.peer_offload` — so ``*-COOP`` policies get
    oracle validation like every silo branch.  Each round picks the
    worst-min-slack edge among those holding an exportable task (queued,
    slack below ``slack_ms``, still feasible appended behind the
    least-loaded other edge), moves that edge's worst-slack feasible task
    to the least-loaded peer, and repeats up to ``max_transfers`` times
    per slice.

    With ``max_transfers == 0`` (or one edge) no exchange ever fires and
    results are identical to running each :class:`Simulator` to
    completion on its own — the existing silo oracle path.
    """

    def __init__(self, sims: list[Simulator], duration: float, *,
                 dt: float = 25.0, slack_ms: float = 0.0,
                 max_transfers: int = 0):
        self.sims = sims
        self.duration = duration
        self.dt = dt
        self.slack_ms = slack_ms
        self.max_transfers = max_transfers
        self.peer_moved = 0

    # -- fleet peer_offload mirrors (oracle-native quantities) ----------
    def _slacks(self, sim: Simulator) -> list[float]:
        proj = sim._projected(sim.edge_queue)
        return [t.sched_deadline - c
                for t, c in zip(sim.edge_queue, proj)]

    def _load(self, sim: Simulator, now: float) -> float:
        busy = max(sim.edge_busy_until - now, 0.0)
        return busy + sum(t.model.t_edge for t in sim.edge_queue)

    def _adopt(self, dst: Simulator, task: Task) -> None:
        """Give the destination edge the state a foreign task needs."""
        m = task.model
        if m.name not in dst.profiles:
            dst.profiles[m.name] = m
            dst.min_edge_t = min(dst.min_edge_t or m.t_edge, m.t_edge)
            dst.adaptive[m.name] = AdaptiveEstimator(static=m.t_cloud)
            dst.stats[m.name] = ModelStats()
            if m.qoe_alpha > 0:
                dst.windows[m.name] = _WindowState(m.qoe_window)

    def _one_transfer(self, now: float) -> bool:
        sims = self.sims
        n = len(sims)
        slacks = [self._slacks(s) for s in sims]
        min_slack = [min(sl, default=float("inf")) for sl in slacks]
        # crashed edges can neither export (their queue was flushed) nor
        # import — infinite load keeps them out of every min() below,
        # mirroring the fleet's ``edge_valid = valid & edge_up`` gate
        load = [float("inf") if s.edge_down else self._load(s, now)
                for s in sims]

        # each edge's best destination load: the global minimum, or the
        # runner-up for the least-loaded edge itself
        lead = min(range(n), key=lambda e: load[e])
        runner_up = min((load[e] for e in range(n) if e != lead),
                        default=float("inf"))
        dst_load = [runner_up if e == lead else load[lead]
                    for e in range(n)]
        exportable = [
            any(sl < self.slack_ms
                and now + dst_load[e] + t.model.t_edge <= t.sched_deadline
                for t, sl in zip(sims[e].edge_queue, slacks[e]))
            for e in range(n)]
        over = [e for e in range(n)
                if min_slack[e] < self.slack_ms and exportable[e]]
        if not over:
            return False
        src = min(over, key=lambda e: min_slack[e])
        dst = min((e for e in range(n) if e != src),
                  key=lambda e: load[e])
        # worst-slack task still feasible behind the destination's load
        cands = [(sl, i) for i, (t, sl) in enumerate(
            zip(sims[src].edge_queue, slacks[src]))
            if sl < self.slack_ms
            and now + load[dst] + t.model.t_edge <= t.sched_deadline]
        if not cands:
            return False
        _, vi = min(cands)
        task = sims[src].edge_queue.pop(vi)
        self._adopt(sims[dst], task)
        sims[dst]._edge_insert(task, sims[dst]._insert_pos(task))
        self.peer_moved += 1
        return True

    def run(self) -> list[Results]:
        for sim in self.sims:
            sim.prime()
        n_slices = max(1, round(self.duration / self.dt))
        coop = self.max_transfers > 0 and len(self.sims) > 1
        for i in range(n_slices):
            t = min((i + 1) * self.dt, self.duration)
            for sim in self.sims:
                sim.run_until(t)
                sim.now = max(sim.now, t)
            if coop:
                for _ in range(self.max_transfers):
                    if not self._one_transfer(t):
                        break
        for sim in self.sims:     # drain in-flight work past the horizon
            sim.run_until(float("inf"))
        return [sim.finalize() for sim in self.sims]
