"""Fleet-scale SPMD scheduler simulation (paper §8.6, TPU-native).

The paper weak-scales its platform to 84 drones / 28 edges by replicating
containers.  Here the *entire fleet* is one JAX program: per-edge scheduler
state is a PyTree of arrays with a leading ``fleet`` axis, each tick applies
the decision kernels of :mod:`repro.core.jax_sched` under ``vmap``, and the
fleet axis is sharded across devices with ``NamedSharding`` — the same
program scales from 1 edge on CPU to 10⁵ edges on a pod.

Modeling simplifications vs the event-driven oracle (documented per §Design):

* fixed time step ``dt`` (default 25 ms) instead of an event heap;
* deterministic execution fractions (edge ``edge_frac·t``, cloud
  ``cloud_frac·t̂ + θ(t) + bw-penalty``) — variability enters via the
  shaped θ trace and the dense cellular-bandwidth signal ``bw`` (the
  signed transfer penalty convention of
  :meth:`repro.sim.network.CloudLatencyModel.shaped_delta`);
* the cloud is a **finite pool**: each edge owns ``cloud_slots``
  busy-until slots (its share of the bounded FaaS concurrency, mirroring
  the oracle's per-edge ``cloud_concurrency``).  A matured task only
  dispatches when a slot is free; while the pool is saturated it stays
  parked on the trigger-time queue (still stealable) and the estimated
  queue-wait — the *depth-aware* k-th order statistic of the slot
  busy-until times, k being the task's cloud-queue position — is folded
  into the t̂ used by routing, migration, stealing triggers and GEMS
  feasibility.  With a large pool the wait is identically zero and the
  elastic model is recovered exactly (bit-identical to the old
  ``min(busy_until) − now`` estimate, which is the k=0 special case);
* tasks matured in the same tick dispatch in queue-slot order (the oracle
  pops in trigger order) — indistinguishable in the elastic limit, an
  approximation under saturation;
* estimator/offer events are batched per tick against the tick's
  pre-state (the oracle interleaves them in event order within one
  instant): DEMS-A observations apply as one masked window update
  (:func:`repro.core.jax_sched.adapt_feed_batch`), and a tick's cloud
  offers (migration victims + the arrival) are admitted in one
  vectorized pass that fills free queue slots in the exact order a
  sequential push loop would.

Supported policies: the oracle's full registry — the §8.2 baselines
(edge-only EDF/HPF, cloud-only CLD, EDF/SJF-E+C, the SOTA1/SOTA2
Kalmia-and-Dedas adaptations), DEM migration, DEMS work stealing with
trigger-time cloud queue and steal-only parking, DEMS-A sliding-window
cloud-latency adaptation (§5.4), GEMS window rescheduling and the
beyond-paper GEMS-B winnability budget.  Per-policy decision rules and
the oracle↔fleet semantic deltas are documented in ``docs/POLICIES.md``;
``tests/test_fleet_jax.py`` checks single-edge agreement with the
discrete-event engine for every policy.

Policy flags are **runtime values** (:class:`PolicyParams`): the compiled
tick program is policy-generic, so a whole scenario × policy × seed sweep
shares one executable.  Sweeps run as *one* compiled program through
:func:`run_fleet_batch` (same-shape replicas, :func:`stack_signals`) or —
across *heterogeneous* scenarios — through :func:`run_batch` on a
:func:`build_fleet_batch` batch, whose :func:`pad_signals` masks every
replica to the max (ticks, edges, models) shape with per-(tick, edge)
validity; padded cells are exact no-ops.  With a 2-D device mesh the
batch shards over a (replica, edge) grid.

The compiled tick scan is exposed step-wise through
:class:`FleetProgram` — ``init`` / ``step_chunk(state, signal_window)``
— the seam between *replaying a scenario* and *running a fleet*: every
replay entry point above is a thin :meth:`FleetProgram.run` loop over
``step_chunk`` (bitwise-identical to the pre-refactor single-scan
calls), and the online :class:`repro.serve.controller.FleetController`
feeds the very same ``step_chunk`` with telemetry-built windows.

Every entry point takes a ``trace=`` :class:`repro.obs.trace.TraceSpec`
— the flight recorder.  It taps the tick scan's carry and emits dense
per-tick decision counters and/or the adapted-t̂ stream as extra scan
outputs (:class:`FleetResult`); the taps are read-only and
valid-masked, so traced runs produce bit-identical scheduler results,
and a trace-off run compiles the very same program as before the
recorder existed.  Host-side aggregation (QoS/QoE time series, tail
percentiles, conservation ledger, Perfetto export) lives in
:mod:`repro.obs.metrics`.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_sched as js
from repro.core import schedulers as _sched
from repro.core.task import ModelProfile
from repro.kernels import sched_ops
from repro.obs import trace as obs_trace
from repro.obs.trace import (TickCounters, TraceSpec, hist_counts,
                             resolve_spec, zero_counters)
from repro.sim import network

EDGE_CAP = 32
CLOUD_CAP = 64
SUBSTEPS = 6      # max edge executor actions (drops/starts) per tick
CLOUD_SLOTS = 16  # default per-edge FaaS share (engine's cloud_concurrency)


# Fleet-supported policy names: the oracle's full registry.  Flag sets
# derive from core.schedulers._POLICIES so the two simulators cannot
# drift apart.
_FLEET_POLICY_NAMES = tuple(_sched._POLICIES)
_FLEET_FLAGS = ("migration", "stealing", "gems", "adaptive", "use_cloud",
                "use_edge", "edge_feasibility_check", "edge_priority",
                "cloud_accepts_negative", "sota1", "sota2", "gems_budget")
_FLEET_POLICIES = {
    name: {k: v for k, v in _sched._POLICIES[name].items()
           if k in _FLEET_FLAGS}
    for name in _FLEET_POLICY_NAMES
}


class PolicyParams(NamedTuple):
    """Policy flags as traced scalars (leading replica axis in batches).

    Making the flags runtime values keeps the compiled tick program
    policy-generic: one executable serves every policy (and, stacked, a
    whole registry × policy × seed sweep), at the price of computing each
    feature's masked no-op when its flag is off.
    """

    migration: jax.Array        # bool[]
    stealing: jax.Array         # bool[]
    gems: jax.Array             # bool[]
    use_cloud: jax.Array        # bool[]
    use_edge: jax.Array         # bool[]  False → CLD (cloud-only routing)
    feas_check: jax.Array       # bool[]  False → EDF/HPF unconditional insert
    edge_prio: jax.Array        # i32[]   jax_sched.PRIO_{EDF,HPF,SJF}
    cloud_neg_ok: jax.Array     # bool[]  SJF-E+C sends γ^C≤0 tasks anyway
    sota1: jax.Array            # bool[]  Kalmia/D3 urgency routing (§8.2)
    sota2: jax.Array            # bool[]  Dedas ACT routing (§8.2)
    gems_budget: jax.Array      # bool[]  GEMS-B winnability gate
    urgent_deadline: jax.Array  # f32[]   SOTA1 urgency threshold [ms]
    adaptive: jax.Array         # bool[]
    cooperation: jax.Array      # bool[]
    cloud_margin: jax.Array     # f32[]
    adapt_eps: jax.Array        # f32[]
    adapt_cooling_ms: jax.Array  # f32[]
    coop_slack_ms: jax.Array    # f32[]
    coop_transfer_cap: jax.Array  # i32[] (≤ the program's static rounds)
    cloud_give_up_ms: jax.Array  # f32[] parked-dispatch timeout (+inf = off)


@dataclasses.dataclass(frozen=True)
class FleetPolicy:
    """Policy flags (subset of core.schedulers.Policy).

    Lowered to runtime :class:`PolicyParams` by :meth:`params`; only
    ``adapt_window`` (a buffer *shape*) and ``coop_max_transfers`` (a
    loop bound) stay trace-time static.
    """

    migration: bool = False
    stealing: bool = False
    gems: bool = False
    use_cloud: bool = True
    use_edge: bool = True
    edge_feasibility_check: bool = True
    edge_priority: str = "edf"            # "edf" | "hpf" | "sjf"
    cloud_accepts_negative: bool = False
    sota1: bool = False
    sota2: bool = False
    gems_budget: bool = False
    urgent_deadline: float = 700.0        # SOTA1 urgency threshold [ms]
    cloud_margin: float = 50.0
    # DEMS-A sliding-window cloud-latency adaptation (§5.4): estimator
    # hyper-parameters mirror core.schedulers.AdaptiveEstimator.
    adaptive: bool = False
    adapt_window: int = 10
    adapt_eps: float = 10.0
    adapt_cooling_ms: float = 10_000.0
    # cross-edge cooperation (beyond-paper; fleet-scope work stealing):
    # after each tick, edges whose minimum queue slack drops below
    # ``coop_slack_ms`` export their worst-slack feasible tasks to the
    # least-loaded peer, at most ``coop_max_transfers`` moves per tick.
    cooperation: bool = False
    coop_slack_ms: float = 0.0
    coop_max_transfers: int = 2
    # cloud-dispatch timeout (chaos hardening): a parked cloud task that
    # has waited more than this past its trigger maturity — through an
    # outage, a partition, or pool saturation — is dropped instead of
    # retried forever.  The fleet re-checks every tick, the oracle at
    # every dispatch/recovery event: timeout with bounded retries, the
    # shared convention.  +inf (the default) disables the timeout and is
    # a bitwise no-op on every existing result.
    cloud_give_up_ms: float = float("inf")

    @classmethod
    def from_name(cls, name: str) -> "FleetPolicy":
        coop = name.endswith("-COOP")
        base_name = name[: -len("-COOP")] if coop else name
        if base_name not in _FLEET_POLICIES:
            supported = sorted(_FLEET_POLICIES) + sorted(
                n + "-COOP" for n in _FLEET_POLICIES)
            raise ValueError(f"unknown fleet policy {name!r}; choose from "
                             f"{supported}")
        base = cls(**_FLEET_POLICIES[base_name])
        return dataclasses.replace(base, cooperation=True) if coop else base

    def params(self) -> PolicyParams:
        f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
        prio = {"edf": js.PRIO_EDF, "hpf": js.PRIO_HPF,
                "sjf": js.PRIO_SJF}[self.edge_priority]
        return PolicyParams(
            migration=jnp.asarray(self.migration),
            stealing=jnp.asarray(self.stealing),
            gems=jnp.asarray(self.gems),
            use_cloud=jnp.asarray(self.use_cloud),
            use_edge=jnp.asarray(self.use_edge),
            feas_check=jnp.asarray(self.edge_feasibility_check),
            edge_prio=jnp.asarray(prio, jnp.int32),
            cloud_neg_ok=jnp.asarray(self.cloud_accepts_negative),
            sota1=jnp.asarray(self.sota1),
            sota2=jnp.asarray(self.sota2),
            gems_budget=jnp.asarray(self.gems_budget),
            urgent_deadline=f32(self.urgent_deadline),
            adaptive=jnp.asarray(self.adaptive),
            cooperation=jnp.asarray(self.cooperation),
            cloud_margin=f32(self.cloud_margin),
            adapt_eps=f32(self.adapt_eps),
            adapt_cooling_ms=f32(self.adapt_cooling_ms),
            coop_slack_ms=f32(self.coop_slack_ms),
            coop_transfer_cap=jnp.asarray(self.coop_max_transfers,
                                          jnp.int32),
            cloud_give_up_ms=f32(self.cloud_give_up_ms))


class Profiles(NamedTuple):
    """Array-of-struct model table (M models)."""

    t_edge: jax.Array
    t_cloud: jax.Array
    deadline: jax.Array
    gamma_e: jax.Array
    gamma_c: jax.Array
    cost_e: jax.Array
    cost_c: jax.Array
    steal_rank: jax.Array
    qoe_alpha: jax.Array
    qoe_beta: jax.Array
    qoe_window: jax.Array

    @classmethod
    def build(cls, models: list[ModelProfile],
              pad_to: Optional[int] = None) -> "Profiles":
        """Build the table; ``pad_to`` appends inert models for padded
        cross-scenario batching.  Pad values are chosen so no reduction
        over the model axis can see them: huge latencies keep
        ``min(t_edge)`` (the stealing gate) and window expiry untouched,
        zero utilities keep every masked sum exact."""
        f = jnp.asarray
        prof = cls(
            t_edge=f([m.t_edge for m in models], jnp.float32),
            t_cloud=f([m.t_cloud for m in models], jnp.float32),
            deadline=f([m.deadline for m in models], jnp.float32),
            gamma_e=f([m.gamma_edge for m in models], jnp.float32),
            gamma_c=f([m.gamma_cloud for m in models], jnp.float32),
            cost_e=f([m.cost_edge for m in models], jnp.float32),
            cost_c=f([m.cost_cloud for m in models], jnp.float32),
            steal_rank=f([m.steal_rank() for m in models], jnp.float32),
            qoe_alpha=f([m.qoe_alpha for m in models], jnp.float32),
            qoe_beta=f([m.qoe_beta for m in models], jnp.float32),
            qoe_window=f([m.qoe_window for m in models], jnp.float32),
        )
        if pad_to is None or pad_to <= len(models):
            return prof
        pad_val = dict(t_edge=js.POS, t_cloud=js.POS, deadline=js.POS,
                       qoe_window=js.POS)
        width = pad_to - len(models)
        return cls(**{
            name: jnp.concatenate([getattr(prof, name), jnp.full(
                width, pad_val.get(name, 0.0), jnp.float32)])
            for name in cls._fields})


class EdgeState(NamedTuple):
    """Per-edge scheduler state (leading fleet axis added by vmap)."""

    eq: js.EdgeQueue
    cq: js.CloudQueue
    cq_model: jax.Array        # i32[Qc] model ids of cloud-queued tasks
    busy_rem: jax.Array        # f32[] remaining edge execution time
    # finite FaaS pool: busy-until time per cloud slot (this edge's share
    # of the bounded Lambda concurrency; slot free iff busy_until <= now).
    # In padded batches the array is oversized and slots ≥ n_slots are
    # parked at +inf — never free, invisible to the k-th order statistic.
    cloud_busy_until: jax.Array  # f32[S]
    n_slots: jax.Array         # i32[] this edge's real pool depth
    # cloud-queue entries that have waited for a saturated pool at least
    # once: when their slot finally frees they re-run the oracle's
    # dispatch-time JIT check (never set in the elastic limit)
    cq_blocked: jax.Array      # bool[Qc]
    seq: jax.Array             # i32[] insertion counter
    # stats
    n_success: jax.Array       # i32[M]
    n_miss: jax.Array          # i32[M]
    n_drop: jax.Array          # i32[M]
    n_stolen: jax.Array        # i32[M]
    n_edge_exec: jax.Array     # i32[M] tasks executed on the edge
    qos_utility: jax.Array     # f32[]
    # GEMS window state
    lam: jax.Array             # i32[M]
    lam_hat: jax.Array         # i32[M]
    # per-window arrival forecast (GEMS-B): events seen in the *previous*
    # window, the base of the winnability check's remaining-arrival
    # estimate (oracle _WindowState.prev_lam)
    prev_lam: jax.Array        # i32[M]
    win_end: jax.Array         # f32[M]
    qoe_utility: jax.Array     # f32[]
    windows_met: jax.Array     # i32[M]
    # cross-edge cooperation stats
    n_peer_out: jax.Array      # i32[] tasks exported to a peer edge
    n_peer_in: jax.Array       # i32[] tasks imported from a peer edge
    # DEMS-A estimator state (§5.4): per-model sliding-window t̂
    adapt: js.AdaptState


class FleetResult(NamedTuple):
    """A fleet run with flight-recorder telemetry (``trace=TraceSpec``).

    ``t_hat`` carries ``adapt.current`` out of the tick scan — the
    scheduler's per-tick adapted cloud-latency estimate, enabling
    Fig. 12-style adaptation-dynamics plots.  Its shape is ``[T, E, M]``
    from :func:`run_fleet` and ``[R, T, E, M]`` from both batch entry
    points (:func:`run_fleet_batch` and :func:`run_batch`), where T is
    the tick count, E the (padded) edge count, M the (padded) model
    count and R the replica count.  ``counters`` carries the per-tick
    decision stream (:class:`repro.obs.trace.TickCounters`, leaves
    ``[T, E, …]`` / ``[R, T, E, …]``).  Streams not requested by the
    :class:`~repro.obs.trace.TraceSpec` are ``None``.
    """

    final: EdgeState
    t_hat: Optional[jax.Array] = None        # f32[(R,) T, E, M]
    counters: Optional[TickCounters] = None  # [(R,) T, E, …] leaves


def _tr_add(tr: Optional[TickCounters], **deltas) -> Optional[TickCounters]:
    """Accumulate trace contributions; statically a no-op when the
    flight recorder is off (``tr is None``), so the untraced program is
    byte-identical to the pre-recorder one."""
    if tr is None:
        return None
    return tr._replace(**{k: getattr(tr, k) + v for k, v in deltas.items()})


def init_state(prof: Profiles, adapt_window: int = 10,
               cloud_slots: int = CLOUD_SLOTS,
               total_slots: Optional[int] = None) -> EdgeState:
    """Fresh per-edge state.  ``total_slots`` oversizes the busy-until
    array for padded batches; slots beyond ``cloud_slots`` start (and
    stay) at +inf so they are never free."""
    m = prof.t_edge.shape[0]
    total = cloud_slots if total_slots is None else total_slots
    zi = jnp.zeros(m, jnp.int32)
    return EdgeState(
        eq=js.empty_edge_queue(EDGE_CAP), cq=js.empty_cloud_queue(CLOUD_CAP),
        cq_model=jnp.zeros(CLOUD_CAP, jnp.int32),
        busy_rem=jnp.zeros(()),
        # strong f32 (not a weak Python-float fill): the stepped state
        # comes back strongly typed, and a weak→strong aval flip would
        # retrace the program on the second step_chunk window
        cloud_busy_until=jnp.where(jnp.arange(total) < cloud_slots,
                                   0.0, js.POS).astype(jnp.float32),
        n_slots=jnp.asarray(cloud_slots, jnp.int32),
        cq_blocked=jnp.zeros(CLOUD_CAP, bool),
        seq=jnp.zeros((), jnp.int32),
        n_success=zi, n_miss=zi, n_drop=zi, n_stolen=zi, n_edge_exec=zi,
        qos_utility=jnp.zeros(()),
        lam=zi, lam_hat=zi, prev_lam=zi, win_end=prof.qoe_window,
        qoe_utility=jnp.zeros(()), windows_met=zi,
        n_peer_out=jnp.zeros((), jnp.int32),
        n_peer_in=jnp.zeros((), jnp.int32),
        adapt=js.adapt_init(prof.t_cloud, adapt_window))


def _pool_wait(st: EdgeState, now) -> jax.Array:
    """Depth-aware queue-wait estimate for the next dispatch-bound task.

    The task joining the cloud queue sits behind ``pending`` entries that
    will each grab a slot, so it waits for the k-th slot to free — the
    k-th order statistic of the busy-until times (ROADMAP item), not the
    time until *one* slot frees.  With an empty queue this reduces to the
    old ``min(busy_until) − now``; in the elastic limit (ample pool) it
    is identically zero, bit-for-bit."""
    pending = (st.cq.valid & ~st.cq.steal_only).sum()
    k = jnp.clip(pending, 0, st.n_slots - 1)
    return jnp.maximum(jnp.sort(st.cloud_busy_until)[k] - now, 0.0)


def _free_slot_gate(busy_until: jax.Array, now,
                    want: jax.Array) -> jax.Array:
    """Admit the first ``n_free`` wanting tasks, in slot order.

    ``want`` marks queue entries that would each occupy one cloud slot;
    the gate is True for those that find a free slot this tick (tasks
    popped-and-dropped without dispatching never consume a slot, so they
    are gated by the same dispatch count — as in the oracle's pop loop).
    """
    wi = want.astype(jnp.int32)
    taken_before = jnp.cumsum(wi) - wi          # exclusive dispatch count
    return taken_before < (busy_until <= now).sum()


def _occupy_slots(busy_until: jax.Array, now, dispatch: jax.Array,
                  end_time: jax.Array) -> jax.Array:
    """Assign each dispatched task a distinct free slot, vectorized.

    Dispatched task k (in queue order) fills the k-th free slot with its
    completion time; ``dispatch`` must already be gated by
    :func:`_free_slot_gate` so ranks never exceed the free count.
    """
    s = busy_until.shape[0]
    di = dispatch.astype(jnp.int32)
    drank = jnp.cumsum(di) - di
    end_by_rank = jnp.zeros(s).at[
        jnp.where(dispatch, drank, s)].set(end_time, mode="drop")
    free = busy_until <= now
    fi = free.astype(jnp.int32)
    frank = jnp.cumsum(fi) - fi
    fill = free & (frank < dispatch.sum())
    return jnp.where(fill, end_by_rank[frank], busy_until)


def _t_cloud_cur(st: EdgeState, prof: Profiles, pp: PolicyParams,
                 now) -> jax.Array:
    """Scheduler's current cloud-latency estimate t̂ per model (§5.4),
    plus the depth-aware finite-pool queue-wait estimate (zero while the
    pool has headroom), so routing, migration, stealing triggers and GEMS
    feasibility all see the congested cloud."""
    base = jnp.where(pp.adaptive, st.adapt.current, prof.t_cloud)
    return base + _pool_wait(st, now)


class FleetSignals(NamedTuple):
    """Dense per-tick scenario signals driving the fleet simulator.

    Produced either by :func:`default_signals` (the paper's steady
    3-drones-per-edge workload) or by
    :func:`repro.scenarios.compile.compile_fleet` (mobility, handover,
    bursts, churn, outages, heterogeneous edges).  ``valid`` marks the
    live (tick, edge) cells: all-True for a plain run, the real-region
    mask after :func:`pad_signals`; the tick function reverts every
    invalid cell to its pre-tick state, making padding exact.
    """

    times: jax.Array       # f32[T]    tick start times [ms]
    theta: jax.Array       # f32[T,E]  per-edge added WAN latency θ(t)
    bw: jax.Array          # f32[T,E]  per-edge cellular bandwidth [Mbps]
    arrive: jax.Array      # bool[T,E,M] model m arrives at edge e this tick
    order: jax.Array       # i32[T,E,M] randomized insertion order (§3.3)
    load_mult: jax.Array   # f32[T,E]  edge execution-time multiplier
    cloud_up: jax.Array    # bool[T]   cloud FaaS availability
    valid: jax.Array       # bool[T,E] live cells (False ⇒ padded no-op)
    # sampled execution-duration multipliers, axis -1 = (edge, cloud);
    # exactly 1.0 in deterministic mode, so the default lane is a
    # bitwise no-op on every act computation it scales
    exec_jit: jax.Array    # f32[T,E,M,2]
    # chaos-engine availability lanes (repro.faults): all-True outside a
    # fault schedule, so fault-free signals compile to the same program
    # results as before the lanes existed
    edge_up: jax.Array     # bool[T,E] False ⇒ edge crashed (queue flushed)
    link_up: jax.Array     # bool[T,E] False ⇒ edge↔cloud link partitioned


# ---------------------------------------------------------------------------
# per-tick logic for one edge
# ---------------------------------------------------------------------------

def _resolve_cloud(st: EdgeState, tr: Optional[TickCounters],
                   tspec: TraceSpec, prof: Profiles, pp: PolicyParams, now,
                   theta, bw_pen, cloud_frac, cloud_up, link_up, jit_c):
    """Dispatch matured cloud tasks into the finite FaaS pool.

    During a cloud outage (``cloud_up`` False) matured tasks stay parked
    on the trigger-time queue; the dispatch-time deadline check settles
    their fate once the cloud returns — mirroring the oracle's behavior.
    Likewise, while the slot pool is saturated, matured tasks stay parked
    (still stealable, like the oracle's ``cloud_pending``) and retry once
    a slot frees; a dispatched task occupies its slot for the whole
    actual duration ``cloud_frac·t̂ + θ(t) + bw-penalty``.

    With ``pp.adaptive`` (DEMS-A, §5.4) dispatch adds the oracle's JIT
    check against the *adapted* estimate t̂: tasks it predicts to miss are
    skipped (dropped, feeding the cooling timer) instead of dispatched —
    without consuming a slot; dispatched tasks fire ``on_sent`` and
    ``observe`` their actual duration, applied as one batched masked
    window update (:func:`repro.core.jax_sched.adapt_feed_batch`).
    """
    # a partitioned edge↔cloud link parks dispatch exactly like a cloud
    # outage seen from this edge; the per-edge link_up lane composes with
    # the fleet-wide cloud_up mask
    mature = st.cq.valid & (st.cq.trigger <= now) & cloud_up & link_up
    # cloud-dispatch timeout (bounded retries): a parked task that has
    # waited more than cloud_give_up_ms past its trigger maturity —
    # through an outage, a partition, or pool saturation — gives up and
    # drops.  +inf (the default) never fires.
    timed_out = st.cq.valid & ~st.cq.steal_only & \
        (now - st.cq.trigger > pp.cloud_give_up_ms)
    run = mature & ~st.cq.steal_only & ~timed_out
    fits_a = now + st.adapt.current[st.cq_model] <= st.cq.deadline
    # the oracle JIT-checks every pop against the static estimate; in
    # the fleet model tasks normally mature within one tick of their
    # feasibility-checked trigger, so the check is redundant — except
    # for tasks that sat out a saturated pool, which re-run it here
    # (never taken in the elastic limit).  Outage-parked tasks keep
    # the documented modeling simplification of settling via the
    # dispatch-time deadline check instead (the oracle JIT-drops them
    # at recovery without consuming a slot); under a small pool the
    # difference is bounded to one pool-depth of doomed dispatches,
    # since everything behind them fails the slot gate, turns
    # cq_blocked, and does re-run this check.
    fits_s = ~st.cq_blocked | (now + prof.t_cloud[st.cq_model]
                               <= st.cq.deadline)
    fits = jnp.where(pp.adaptive, fits_a, fits_s)
    avail = _free_slot_gate(st.cloud_busy_until, now, run & fits)
    dispatch = run & fits & avail
    skipped = run & ~fits & avail     # popped + JIT-dropped, slot stays free
    # the sampled multiplier scales the compute body only — θ(t) and the
    # bandwidth penalty stay additive, like the oracle's shaped_delta
    act = cloud_frac * prof.t_cloud[st.cq_model] * jit_c[st.cq_model] \
        + theta + bw_pen
    success = dispatch & (now + act <= st.cq.deadline)
    util = jnp.where(success, prof.gamma_c[st.cq_model],
                     jnp.where(dispatch, -prof.cost_c[st.cq_model],
                               0.0)).sum()
    add = functools.partial(jax.ops.segment_sum,
                            num_segments=prof.t_edge.shape[0])
    n_success = st.n_success + add(success.astype(jnp.int32), st.cq_model)
    n_miss = st.n_miss + add((dispatch & ~success).astype(jnp.int32),
                             st.cq_model)
    dropped = mature & st.cq.steal_only      # not stolen in time (§5.3)
    n_drop = st.n_drop + add((dropped | skipped | timed_out)
                             .astype(jnp.int32), st.cq_model)
    # flight recorder: read-only taps (drops by cause, pool pressure,
    # tail evidence from the settled tasks' slack/latency)
    tr = _tr_add(
        tr, cloud_dispatch=dispatch.sum(), pool_blocked=(run & ~avail).sum(),
        drop_infeasible=skipped.sum(), drop_unstolen=dropped.sum(),
        drop_timeout=timed_out.sum(),
        slack_hist=hist_counts(st.cq.deadline - (now + act), success, tspec),
        latency_hist=hist_counts(
            (now + act) - (st.cq.deadline - prof.deadline[st.cq_model]),
            success, tspec))
    settled = dispatch | skipped | dropped | timed_out  # blocked stay parked
    new_valid = st.cq.valid & ~settled
    st = st._replace(cq=st.cq._replace(valid=new_valid),
                     cloud_busy_until=_occupy_slots(
                         st.cloud_busy_until, now, dispatch, now + act),
                     cq_blocked=(st.cq_blocked | (run & ~avail)) & new_valid,
                     n_success=n_success, n_miss=n_miss, n_drop=n_drop,
                     qos_utility=st.qos_utility + util)
    sent = dispatch & pp.adaptive
    st = st._replace(adapt=js.adapt_feed_batch(
        st.adapt, st.cq_model, sent, sent, act, skipped & pp.adaptive,
        now, prof.t_cloud, pp.adapt_eps, pp.adapt_cooling_ms,
        max_obs=st.cloud_busy_until.shape[0]))
    return _gems_bulk(st, prof, success & pp.gems,
                      (dispatch | skipped | dropped | timed_out) & pp.gems,
                      st.cq_model), tr


def _gems_bulk(st: EdgeState, prof: Profiles, success_mask, done_mask,
               model_ids) -> EdgeState:
    """Window counters for a batch of task completions/drops."""
    m = prof.t_edge.shape[0]
    add = functools.partial(jax.ops.segment_sum, num_segments=m)
    lam = st.lam + add(done_mask.astype(jnp.int32), model_ids)
    lam_hat = st.lam_hat + add(success_mask.astype(jnp.int32), model_ids)
    return st._replace(lam=lam, lam_hat=lam_hat)


def _gems_act(st: EdgeState, tr: Optional[TickCounters], tspec: TraceSpec,
              prof: Profiles, pp: PolicyParams, now, theta, bw_pen,
              cloud_frac, link_up, jit_c):
    """Alg. 1: reschedule lagging models, close expired windows.

    Rescheduled tasks go through the same finite pool as the dispatch
    path: the feasibility gate sees the queue-wait-folded t̂, moves are
    capped by the free slots this tick (the rest stay on the edge queue
    and may move next tick if still lagging), and each move occupies a
    slot for the actual-duration model ``cloud_frac·t̂ + θ + bw-penalty``.

    Plain GEMS keeps the legacy modeling simplification of resolving the
    move's *outcome* at the deterministic estimate t̂ (no shaping) — the
    elastic-limit behavior this refactor preserves bit-for-bit; only
    GEMS-A resolves at the actual-duration model and feeds completions to
    the estimator (mirroring the oracle, where rescheduled tasks go
    through the instrumented cloud dispatch path).

    GEMS-B (``pp.gems_budget``, beyond-paper) adds the winnability gate:
    once a window is mathematically lost (per the ``prev_lam`` arrival
    forecast) the Alg-1 flood stops, and only tasks already *doomed* on
    the edge (projected completion past their scheduling deadline) still
    move — a pure QoS rescue, since no QoE is recoverable this window.
    """
    m = prof.t_edge.shape[0]
    rate = st.lam_hat / jnp.maximum(st.lam, 1)
    lagging = (st.lam > 0) & (rate < prof.qoe_alpha)
    lost = pp.gems_budget & ~js.gems_winnable(
        st.lam, st.lam_hat, st.prev_lam, prof.qoe_alpha, now, st.win_end,
        prof.qoe_window)
    proj = js.projected_completions(st.eq, now,
                                    jnp.maximum(st.busy_rem, 0.0))
    doomed = proj > st.eq.deadline

    # move pending edge tasks of lagging models to the cloud (trigger=now,
    # resolved immediately into the free slots of the finite pool);
    # feasibility and success use the absolute deadline, as in the
    # oracle's rescan/dispatch path.
    t_hat = _t_cloud_cur(st, prof, pp, now)
    feas = now + t_hat[st.eq.model] <= st.eq.abs_dl
    # a partitioned link halts GEMS pool migration across it (the lane
    # is all-True outside a fault schedule, so this gate is free)
    cand = (st.eq.valid & lagging[st.eq.model]
            & (prof.gamma_c[st.eq.model] > 0) & feas) & pp.gems & link_up
    want = cand & (~lost[st.eq.model] | doomed)
    move = want & _free_slot_gate(st.cloud_busy_until, now, want)
    # slots are *held* for the actual duration either way; only the
    # outcome model differs between GEMS (estimate) and GEMS-A (actual)
    hold = cloud_frac * prof.t_cloud[st.eq.model] * jit_c[st.eq.model] \
        + theta + bw_pen
    act = jnp.where(pp.adaptive, hold, prof.t_cloud[st.eq.model])
    success = move & (now + act <= st.eq.abs_dl)
    tr = _tr_add(
        tr, gems_moved=move.sum(),
        gems_withheld=(cand & lost[st.eq.model] & ~doomed).sum(),
        slack_hist=hist_counts(st.eq.abs_dl - (now + act), success, tspec),
        latency_hist=hist_counts(
            (now + act) - (st.eq.abs_dl - prof.deadline[st.eq.model]),
            success, tspec))
    add = functools.partial(jax.ops.segment_sum, num_segments=m)
    util = jnp.where(success, prof.gamma_c[st.eq.model],
                     jnp.where(move, -prof.cost_c[st.eq.model], 0.0)).sum()
    fed = move & pp.adaptive
    st = st._replace(adapt=js.adapt_feed_batch(
        st.adapt, st.eq.model, fed, fed, act,
        jnp.zeros_like(fed), now, prof.t_cloud, pp.adapt_eps,
        pp.adapt_cooling_ms, max_obs=st.cloud_busy_until.shape[0]))
    st = st._replace(
        eq=js.edge_remove(st.eq, move),
        cloud_busy_until=_occupy_slots(st.cloud_busy_until, now, move,
                                       now + hold),
        n_success=st.n_success + add(success.astype(jnp.int32), st.eq.model),
        n_miss=st.n_miss + add((move & ~success).astype(jnp.int32),
                               st.eq.model),
        qos_utility=st.qos_utility + util)
    st = _gems_bulk(st, prof, success, move, st.eq.model)

    # tumbling-window close (Eqn 2)
    expired = (now > st.win_end) & pp.gems
    met = expired & (st.lam > 0) & (st.lam_hat / jnp.maximum(st.lam, 1)
                                    >= prof.qoe_alpha)
    qoe = jnp.where(met, prof.qoe_beta, 0.0).sum()
    return st._replace(
        lam=jnp.where(expired, 0, st.lam),
        lam_hat=jnp.where(expired, 0, st.lam_hat),
        # closing window's event count becomes the next window's arrival
        # forecast (GEMS-B winnability base)
        prev_lam=jnp.where(expired, st.lam, st.prev_lam),
        win_end=jnp.where(expired, st.win_end + prof.qoe_window, st.win_end),
        qoe_utility=st.qoe_utility + qoe,
        windows_met=st.windows_met + met.astype(jnp.int32)), tr


def _offer_cloud_many(st: EdgeState, prof: Profiles, pp: PolicyParams, now,
                      models, deadlines, t_edges, enable,
                      t_cur=None) -> tuple[EdgeState, jax.Array]:
    """Vectorized cloud admission (Policy.offer_cloud) for a task batch.

    ``enable`` marks offered candidates in slot order; accepted ones fill
    the cloud queue's free slots in ascending order — exactly the slots a
    sequential ``cloud_push`` loop would pick.  Every policy check reads
    the tick's pre-offer state (batched-per-tick: an earlier offer in the
    same batch does not shift a later one's queue-depth estimate — the
    module-header simplification).  ``t_edges`` are the tasks' *effective*
    edge latencies (speed factor folded in), kept on the cloud queue for
    steal decisions.

    Feasibility and trigger times use the DEMS-A-adapted t̂ when the
    policy is adaptive — plus the finite-pool queue-wait estimate, so a
    congested cloud pulls stealing triggers earlier and fails the
    feasibility gate sooner; a policy-level rejection then counts as a
    *skip* for the estimator's cooling logic (oracle ``_offer_cloud``).
    Returns ``(state, pushed, accepted)`` — ``accepted & ~pushed`` lost
    the race for a free queue slot (a capacity drop, not a policy one);
    ``t_cur`` lets the caller reuse an already-computed
    :func:`_t_cloud_cur` vector for the same state.
    """
    if t_cur is None:
        t_cur = _t_cloud_cur(st, prof, pp, now)
    t_hat = t_cur[models]
    feasible = now + t_hat <= deadlines
    # SJF-E+C (cloud_neg_ok) sends γ^C≤0 tasks to the cloud anyway; every
    # other policy rejects (or, stealing, parks) them
    negative = (prof.gamma_c[models] <= 0) & ~pp.cloud_neg_ok
    trig_steal = jnp.where(negative, deadlines - t_edges,
                           jnp.maximum(now, deadlines - t_hat
                                       - pp.cloud_margin))
    accept_steal = enable & feasible & jnp.where(negative,
                                                 trig_steal >= now, True)
    accept_plain = enable & feasible & ~negative
    accept = pp.use_cloud & jnp.where(pp.stealing, accept_steal,
                                      accept_plain)
    trigger = jnp.where(pp.stealing, trig_steal, now)
    steal_only = jnp.where(pp.stealing, negative, False)

    free = ~st.cq.valid
    qc = free.shape[0]
    ai = accept.astype(jnp.int32)
    arank = jnp.cumsum(ai) - ai
    pushed = accept & (arank < free.sum())
    tgt = jnp.where(pushed, arank, qc)

    def by_rank(vals):
        return jnp.zeros(qc, vals.dtype).at[tgt].set(vals, mode="drop")

    fi = free.astype(jnp.int32)
    frank = jnp.cumsum(fi) - fi
    fill = free & (frank < pushed.sum())

    def put(old, vals):
        return jnp.where(fill, by_rank(vals)[frank], old)

    st = st._replace(
        cq=js.CloudQueue(
            valid=st.cq.valid | fill,
            trigger=put(st.cq.trigger, trigger),
            t_edge=put(st.cq.t_edge, t_edges),
            deadline=put(st.cq.deadline, deadlines),
            steal_only=put(st.cq.steal_only, steal_only),
            rank=put(st.cq.rank, prof.steal_rank[models])),
        cq_model=put(st.cq_model, models),
        cq_blocked=st.cq_blocked & ~fill)
    skip = enable & ~accept & pp.use_cloud & pp.adaptive
    st = st._replace(adapt=js.adapt_feed_batch(
        st.adapt, models, jnp.zeros_like(skip), jnp.zeros_like(skip),
        jnp.zeros_like(t_hat), skip, now, prof.t_cloud, pp.adapt_eps,
        pp.adapt_cooling_ms, with_obs=False))
    return st, pushed, accept


def _route_arrival(st: EdgeState, tr: Optional[TickCounters],
                   prof: Profiles, pp: PolicyParams, now,
                   model, arrive, load_mult, edge_up=True):
    """Task-scheduler routing for one arriving task (§5.1–5.2, §8.2).

    ``load_mult`` is the edge's speed factor: the effective edge latency
    ``load_mult·t_edge`` is stored on the queues, so feasibility, JIT
    checks, stealing and execution all see the heterogeneous speed —
    matching the oracle compiler, which folds it into the model table.

    Every routing rule of the oracle registry is a runtime branch of the
    same program: the queue position comes from the policy's priority key
    (EDF deadline / HPF utility rate / SJF execution time), ``use_edge``
    off sends everything cloud-ward (CLD), ``feas_check`` off inserts
    unconditionally (edge-only EDF/HPF; the executor's JIT check culls
    late heads), SOTA1 retries infeasible non-urgent tasks with a 10 %
    *scheduling-only* deadline buffer, and SOTA2 admits a
    single-violation insert only when it lowers the queue's mean
    completion time (Dedas ACT rule).

    Migration victims and the redirected arrival go to the cloud through
    *one* vectorized :func:`_offer_cloud_many` call (victims in queue-slot
    order, then the arrival — the same admission order as the old
    sequential offer loop); cloud offers always use the *absolute*
    deadline.
    """
    abs_dl = now + prof.deadline[model]
    te = prof.t_edge[model] * load_mult
    key0 = js.edge_priority_key(pp.edge_prio, abs_dl, te,
                                prof.gamma_e[model])
    feas0 = js.insert_feasible(st.eq, now, st.busy_rem, key0, te, abs_dl)
    victims = js.victim_mask(st.eq, now, st.busy_rem, key0, te)

    # SOTA1 (Kalmia+D3): an infeasible non-urgent task retries with a
    # 10 % deadline buffer; success is still judged at abs_dl, so bought
    # slack can turn into an edge miss — the adaptation's known cost.
    sched1 = abs_dl + 0.1 * prof.deadline[model]
    feas1 = js.insert_feasible(st.eq, now, st.busy_rem, sched1, te, sched1)
    take_ext = (pp.sota1 & ~feas0 & feas1
                & (prof.deadline[model] > pp.urgent_deadline))

    # SOTA2 (Dedas): violations caused by the insert — none: insert;
    # more than one: cloud; exactly one: keep the schedule whose mean
    # completion time is lower (inserting nearly always raises it).
    nviol = victims.sum() + (~feas0).astype(jnp.int32)
    act_ok = js.act_improves(st.eq, now, st.busy_rem, key0, te)
    sota2_ok = (nviol == 0) | ((nviol == 1) & feas0 & act_ok)

    t_cur = _t_cloud_cur(st, prof, pp, now)
    migrate_ok = js.migration_decision(
        st.eq, victims, now, model, abs_dl, prof.gamma_e,
        prof.gamma_c, t_cur)
    plain_ok = feas0 & jnp.where(pp.migration,
                                 ~victims.any() | migrate_ok, True)
    edge_ok = jnp.where(pp.sota1, feas0 | take_ext,
                        jnp.where(pp.sota2, sota2_ok,
                                  jnp.where(pp.feas_check, plain_ok,
                                            True)))
    # a crashed edge admits nothing: arrivals re-route cloudward (and
    # drop there for cloudless policies), matching the oracle's crashed
    # _route convention
    insert_edge = arrive & pp.use_edge & edge_ok & edge_up
    vic = victims & insert_edge & pp.migration
    to_cloud = arrive & ~insert_edge
    key = jnp.where(take_ext, sched1, key0)
    sched_dl = jnp.where(take_ext, sched1, abs_dl)

    models = jnp.concatenate([st.eq.model, jnp.asarray(model)[None]])
    dls = jnp.concatenate([st.eq.abs_dl, jnp.asarray(abs_dl)[None]])
    tes = jnp.concatenate([st.eq.t_edge, jnp.asarray(te)[None]])
    offer = jnp.concatenate([vic, jnp.asarray(to_cloud)[None]])
    st, pushed, accepted = _offer_cloud_many(st, prof, pp, now, models, dls,
                                             tes, offer, t_cur=t_cur)
    add = functools.partial(jax.ops.segment_sum,
                            num_segments=prof.t_edge.shape[0])
    eq = js.edge_remove(st.eq, vic)
    eq, ok = js.edge_push(eq, key, st.seq, te, sched_dl, model,
                          enable=insert_edge, abs_dl=abs_dl)
    # a full edge queue loses the task (edge-only policies cannot shed to
    # the cloud): account it as a drop so tasks stay conserved
    lost = (insert_edge & ~ok).astype(jnp.int32)
    tr = _tr_add(
        tr, arrivals=arrive.astype(jnp.int32),
        admit_edge=(insert_edge & ok).astype(jnp.int32),
        admit_cloud=pushed.sum(), migrated=vic.sum(),
        drop_infeasible=(offer & ~accepted).sum(),
        drop_qfull=lost + (offer & accepted & ~pushed).sum())
    return st._replace(
        eq=eq, seq=st.seq + arrive.astype(jnp.int32),
        n_drop=st.n_drop.at[model].add(lost)
        + add((offer & ~pushed).astype(jnp.int32), models)), tr


def _edge_execute(st: EdgeState, tr: Optional[TickCounters],
                  tspec: TraceSpec, prof: Profiles, pp: PolicyParams, now,
                  dt, edge_frac, min_edge_t, jit_e, edge_up=True):
    """Edge executor: JIT drops, stealing, starting the next task.

    Queue entries carry the *effective* edge latency (speed factor folded
    in at insert time), so every check and the executed duration reflect
    heterogeneous edge speeds consistently.

    A crashed edge (``edge_up`` False) flushes its queue as drops and
    suspends stealing/starts; the task in flight at crash time still
    completes (``busy_rem`` keeps draining — the model is a scheduler
    crash, not a power cut), and the restart resumes with an empty queue.
    The oracle's crash handler mirrors both choices.
    """
    m_ids = jnp.arange(prof.t_edge.shape[0], dtype=jnp.int32)

    flush = st.eq.valid & ~edge_up
    st = st._replace(
        eq=js.edge_remove(st.eq, flush),
        n_drop=st.n_drop + jax.ops.segment_sum(
            flush.astype(jnp.int32), st.eq.model,
            num_segments=prof.t_edge.shape[0]))
    st = _gems_bulk(st, prof, jnp.zeros_like(flush),
                    flush & pp.gems, st.eq.model)
    tr = _tr_add(tr, drop_crash=flush.sum())

    def body(_, carry):
        s, tr = carry
        idle = s.busy_rem <= 0.0

        # JIT check on the head
        eq_after, head_idx, found = js.edge_pop_head(s.eq)
        head_model = s.eq.model[head_idx]
        head_dl = s.eq.deadline[head_idx]
        head_te = s.eq.t_edge[head_idx]
        head_infeasible = found & (now + head_te > head_dl)
        do_drop = idle & head_infeasible
        s = s._replace(
            eq=jax.tree.map(lambda a, b: jnp.where(do_drop, a, b),
                            eq_after, s.eq),
            n_drop=s.n_drop.at[head_model].add(do_drop.astype(jnp.int32)))
        s = _gems_bulk(s, prof, jnp.zeros_like(m_ids, bool),
                       (m_ids == head_model) & do_drop & pp.gems, m_ids)

        idle = idle & ~head_infeasible
        # stealing (§5.3)
        sidx = js.steal_select(s.cq, s.eq, now,
                               jnp.maximum(s.busy_rem, 0.0), min_edge_t)
        can_steal = idle & (sidx >= 0) & pp.stealing & edge_up
        smodel = s.cq_model[jnp.maximum(sidx, 0)]
        sdl = s.cq.deadline[jnp.maximum(sidx, 0)]
        ste = s.cq.t_edge[jnp.maximum(sidx, 0)]
        s = s._replace(cq=s.cq._replace(
            valid=jnp.where(can_steal,
                            s.cq.valid.at[jnp.maximum(sidx, 0)].set(
                                False), s.cq.valid)),
            n_stolen=s.n_stolen.at[smodel].add(
                can_steal.astype(jnp.int32)))

        # start next task: stolen task first, else the queue head
        eq_after, head_idx, found = js.edge_pop_head(s.eq)
        start_head = idle & ~can_steal & found
        run_model = jnp.where(can_steal, smodel, s.eq.model[head_idx])
        # success is judged at the *absolute* deadline (cloud-queue
        # deadlines already are; SOTA1's scheduling extension must not
        # turn a late finish into a success)
        run_dl = jnp.where(can_steal, sdl, s.eq.abs_dl[head_idx])
        run_te = jnp.where(can_steal, ste, s.eq.t_edge[head_idx])
        start = can_steal | start_head
        act = edge_frac * run_te * jit_e[run_model]
        success = start & (now + act <= run_dl)
        util = jnp.where(success, prof.gamma_e[run_model],
                         jnp.where(start, -prof.cost_e[run_model], 0.0))
        tr = _tr_add(
            tr, drop_infeasible=do_drop.astype(jnp.int32),
            edge_exec=start.astype(jnp.int32),
            slack_hist=hist_counts(run_dl - (now + act), success, tspec),
            latency_hist=hist_counts(
                (now + act) - (run_dl - prof.deadline[run_model]),
                success, tspec))
        s = s._replace(
            eq=jax.tree.map(lambda a, b: jnp.where(start_head, a, b),
                            eq_after, s.eq),
            # carry sub-tick execution debt so tick quantization does not
            # waste edge throughput (finish mid-tick → next task starts
            # from the leftover, like the continuous-time oracle)
            busy_rem=jnp.where(start, s.busy_rem + act, s.busy_rem),
            n_success=s.n_success.at[run_model].add(
                success.astype(jnp.int32)),
            n_edge_exec=s.n_edge_exec.at[run_model].add(
                start.astype(jnp.int32)),
            n_miss=s.n_miss.at[run_model].add(
                (start & ~success).astype(jnp.int32)),
            qos_utility=s.qos_utility + util)
        run_onehot = (m_ids == run_model) & start & pp.gems
        return _gems_bulk(s, prof, run_onehot & success, run_onehot,
                          m_ids), tr

    st, tr = jax.lax.fori_loop(0, SUBSTEPS, body, (st, tr))
    # at most one tick of banked debt; idle edges do not accumulate credit
    return st._replace(busy_rem=jnp.maximum(st.busy_rem - dt, -dt)), tr


def make_step(dt: float, edge_frac: float, cloud_frac: float,
              tspec: TraceSpec = TraceSpec()):
    """Build the policy-generic single-edge tick function (vmapped over
    the fleet); ``prof``/``pp`` are runtime arguments, so one compiled
    step serves every model table and policy in a batch.

    With ``tspec.counters`` the step also returns a
    :class:`~repro.obs.trace.TickCounters` of this tick's decisions —
    every tap is read-only on the scheduler state, so the traced run's
    summaries are bit-identical to the untraced run's; without it the
    second return value is ``None`` and the compiled program is the same
    one as before the flight recorder existed.
    """

    def step(prof: Profiles, pp: PolicyParams, st: EdgeState, inputs):
        # arrive: bool[M]; order: i32[M]; theta/bw/load_mult/valid per-edge
        (now, theta, bw, arrive, order, load_mult, cloud_up, valid,
         exec_jit, edge_up, link_up) = inputs
        # signed cellular transfer penalty (network.py convention); exactly
        # 0.0 at the nominal benchmark bandwidth
        bw_pen = network.bandwidth_penalty_ms(bw)
        # per-model sampled duration multipliers for this (tick, edge)
        jit_e, jit_c = exec_jit[:, 0], exec_jit[:, 1]
        min_edge_t = prof.t_edge.min()     # padded models sit at +inf
        st0 = st
        tr = zero_counters(prof.t_edge.shape[0], tspec) \
            if tspec.counters else None
        st, tr = _resolve_cloud(st, tr, tspec, prof, pp, now, theta, bw_pen,
                                cloud_frac, cloud_up, link_up, jit_c)

        # §3.3: tasks of a segment are inserted in randomized order; the
        # loop is load-bearing — each insertion's feasibility depends on
        # the same tick's earlier insertions — but its per-arrival cloud
        # offers are batched inside _route_arrival
        def route_one(i, carry):
            s, t = carry
            mdl = order[i]
            return _route_arrival(s, t, prof, pp, now, mdl, arrive[mdl],
                                  load_mult, edge_up)
        st, tr = jax.lax.fori_loop(0, prof.t_edge.shape[0], route_one,
                                   (st, tr))
        st, tr = _edge_execute(st, tr, tspec, prof, pp, now, dt, edge_frac,
                               min_edge_t, jit_e, edge_up)
        st, tr = _gems_act(st, tr, tspec, prof, pp, now, theta, bw_pen,
                           cloud_frac, link_up, jit_c)
        # padded (tick, edge) cells are exact no-ops
        st = jax.tree.map(lambda a, b: jnp.where(valid, a, b), st, st0)
        if tr is not None:
            # event counters zero out on padded cells; outcome counters
            # are post-revert state deltas (so they sum to the final
            # summary stats exactly), and gauges read the (possibly
            # reverted) end-of-tick state so the conservation ledger
            # stays exact through a padded tail
            tr = tr._replace(**{
                f: jnp.where(valid, getattr(tr, f),
                             jnp.zeros_like(getattr(tr, f)))
                for f in obs_trace.EVENT_FIELDS})
            tr = tr._replace(
                hit=st.n_success - st0.n_success,
                miss=st.n_miss - st0.n_miss,
                drop=st.n_drop - st0.n_drop,
                stolen=st.n_stolen - st0.n_stolen,
                qos=st.qos_utility - st0.qos_utility,
                qoe=st.qoe_utility - st0.qoe_utility,
                eq_depth=st.eq.valid.sum().astype(jnp.int32),
                cq_depth=st.cq.valid.sum().astype(jnp.int32),
                slots_busy=((st.cloud_busy_until > now + dt)
                            & (jnp.arange(st.cloud_busy_until.shape[0])
                               < st.n_slots)).sum().astype(jnp.int32),
                valid=valid)
        return st, tr

    return step


# ---------------------------------------------------------------------------
# cross-edge peer offload (fleet-level exchange between ticks)
# ---------------------------------------------------------------------------

def peer_offload(fs: EdgeState, now, slack_ms, max_transfers: int, *,
                 enable=True, transfer_cap=None,
                 edge_valid=None) -> EdgeState:
    """Move doomed tasks from overloaded edges to the least-loaded peer.

    Operates on the *stacked* fleet state (leading edge axis).  Each of
    the ``max_transfers`` rounds picks the worst-min-slack edge *among
    those with an actually exportable task* (so an unexportable straggler
    cannot starve other overloaded edges), selects its worst-slack task
    that is still feasible behind the least-loaded other edge's queue,
    and re-homes it — the paper's §5.3 work-stealing idea lifted from
    edge↔cloud to edge↔edge.  Queue ``t_edge`` entries carry the source
    edge's speed factor; destination feasibility reuses them, which is
    conservative when the destination is faster.  Under a sharded fleet
    axis the gathers/scatters lower to cross-device collectives.

    ``max_transfers`` is the static round bound; ``enable`` (the runtime
    cooperation flag) and ``transfer_cap`` (the runtime per-tick cap, ≤
    the bound) mask rounds off per replica, and ``edge_valid`` excludes
    padded edges from both export and import.
    """
    n_edges = fs.busy_rem.shape[0]
    if n_edges < 2 or max_transfers == 0:
        return fs
    ev = jnp.ones(n_edges, bool) if edge_valid is None else edge_valid
    cap = jnp.asarray(max_transfers if transfer_cap is None else
                      transfer_cap, jnp.int32)

    def one_transfer(k, fs: EdgeState) -> EdgeState:
        busy = jnp.maximum(fs.busy_rem, 0.0)
        slacks = jax.vmap(js.queue_slacks, in_axes=(0, None, 0))(
            fs.eq, now, busy)                              # [E, Q]
        min_slack = jnp.where(ev, slacks.min(-1), js.POS)  # [E]
        load = jnp.where(ev, jax.vmap(js.queue_load)(fs.eq, fs.busy_rem),
                         js.POS)                           # [E]

        # each edge's best available destination load (least-loaded other
        # edge): the global minimum, or the runner-up for that edge itself
        lead, best = sched_ops.masked_argmin(load, ev)
        runner_up = jnp.where(jnp.arange(n_edges) == lead, js.POS,
                              load).min()
        dst_load = jnp.where(jnp.arange(n_edges) == lead, runner_up,
                             best)                         # [E]
        exportable = (fs.eq.valid & (slacks < slack_ms)
                      & (now + dst_load[:, None] + fs.eq.t_edge
                         <= fs.eq.deadline)).any(-1)       # [E]
        over = (min_slack < slack_ms) & exportable & ev
        sidx, _ = sched_ops.masked_argmin(min_slack, over)
        src = jnp.maximum(sidx, 0)
        didx, _ = sched_ops.masked_argmin(
            load, ev & (jnp.arange(n_edges) != src))
        dst = jnp.maximum(didx, 0)

        src_eq = jax.tree.map(lambda a: a[src], fs.eq)
        vidx = js.export_select(src_eq, now, busy[src], load[dst], slack_ms)
        ok = (over.any() & (sidx >= 0) & (didx >= 0) & (vidx >= 0)
              & enable & (k < cap))
        vi = jnp.maximum(vidx, 0)

        free = ~fs.eq.valid[dst]
        ok = ok & free.any()
        slot = jnp.argmax(free)
        eq = fs.eq
        moved = js.EdgeQueue(
            valid=eq.valid.at[src, vi].set(False).at[dst, slot].set(True),
            key=eq.key.at[dst, slot].set(src_eq.key[vi]),
            seq=eq.seq.at[dst, slot].set(fs.seq[dst]),
            t_edge=eq.t_edge.at[dst, slot].set(src_eq.t_edge[vi]),
            deadline=eq.deadline.at[dst, slot].set(src_eq.deadline[vi]),
            abs_dl=eq.abs_dl.at[dst, slot].set(src_eq.abs_dl[vi]),
            model=eq.model.at[dst, slot].set(src_eq.model[vi]))
        new_eq = jax.tree.map(lambda a, b: jnp.where(ok, a, b), moved, eq)
        oki = ok.astype(jnp.int32)
        return fs._replace(
            eq=new_eq,
            seq=fs.seq.at[dst].add(oki),
            n_peer_out=fs.n_peer_out.at[src].add(oki),
            n_peer_in=fs.n_peer_in.at[dst].add(oki))

    return jax.lax.fori_loop(0, max_transfers, one_transfer, fs)


def default_signals(n_models: int, *, n_edges: int, drones_per_edge: int = 3,
                    duration_ms: float = 300_000.0, dt: float = 25.0,
                    theta_fn=None, bw_fn=None, seed: int = 0) -> FleetSignals:
    """The paper's steady workload as dense tick signals (§8.1/§8.6).

    ``theta_fn`` / ``bw_fn`` shape the WAN latency and cellular bandwidth
    (defaults: no added latency, nominal bandwidth → zero transfer
    penalty).
    """
    m = n_models
    n_ticks = int(duration_ms / dt)
    rng = np.random.default_rng(seed)

    # one segment per drone per second → per-tick arrival counts; we spread
    # each drone's per-segment task burst across model slots determin.
    times = np.arange(n_ticks, dtype=np.float32) * dt
    arrive = np.zeros((n_ticks, n_edges, m), dtype=bool)
    for e in range(n_edges):
        for d in range(drones_per_edge):
            phase = rng.uniform(0, 1000.0)
            seg_t = np.arange(phase, duration_ms, 1000.0)
            ticks = np.minimum((seg_t / dt).astype(int), n_ticks - 1)
            arrive[ticks, e, :] = True
    theta_t = network.sample_trace(theta_fn, times) if theta_fn \
        else np.zeros(n_ticks, np.float32)
    theta = np.broadcast_to(theta_t[:, None], (n_ticks, n_edges))
    bw_t = network.sample_trace(bw_fn, times) if bw_fn \
        else np.full(n_ticks, network.NOMINAL_BW_MBPS, np.float32)
    bw = np.broadcast_to(bw_t[:, None], (n_ticks, n_edges))
    order = rng.permuted(np.tile(np.arange(m), (n_ticks, n_edges, 1)),
                         axis=2).astype(np.int32)
    return FleetSignals(
        times=jnp.asarray(times), theta=jnp.asarray(theta),
        bw=jnp.asarray(bw), arrive=jnp.asarray(arrive),
        order=jnp.asarray(order),
        load_mult=jnp.ones((n_ticks, n_edges), jnp.float32),
        cloud_up=jnp.ones(n_ticks, bool),
        valid=jnp.ones((n_ticks, n_edges), bool),
        exec_jit=jnp.ones((n_ticks, n_edges, m, 2), jnp.float32),
        edge_up=jnp.ones((n_ticks, n_edges), bool),
        link_up=jnp.ones((n_ticks, n_edges), bool))


def _resolve_policy(policy) -> FleetPolicy:
    return policy if isinstance(policy, FleetPolicy) \
        else FleetPolicy.from_name(policy)


# ---------------------------------------------------------------------------
# mesh sharding
# ---------------------------------------------------------------------------

def _put(a: jax.Array, mesh: jax.sharding.Mesh, names: tuple) -> jax.Array:
    """Place ``a`` with the given per-axis mesh-axis names (None = rep.);
    axes whose size does not divide the mesh axis stay replicated."""
    spec = []
    for i in range(a.ndim):
        n = names[i] if i < len(names) else None
        if n is not None and a.shape[i] % mesh.shape[n] != 0:
            n = None
        spec.append(n)
    return jax.device_put(a, jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(*spec)))


def _shard_leading(tree, mesh: jax.sharding.Mesh, axes: int = 1):
    """Shard every leaf's first ``axes`` dims over the mesh's first axes.

    ``axes=2`` is the (replica, edge) grid of a padded batch: replicas
    fan out over the first mesh axis, each replica's fleet over the
    second — the 2-D NamedSharding of the ROADMAP item.
    """
    names = mesh.axis_names[:axes]
    return jax.tree.map(lambda a: _put(a, mesh, names), tree)


# tick-signal leaves keep the replica axis leading; the edge axis sits at
# a field-dependent position (None = no edge axis)
_SIGNAL_EDGE_AXIS = dict(times=None, theta=2, bw=2, arrive=2, order=2,
                         load_mult=2, cloud_up=None, valid=2, exec_jit=2,
                         edge_up=2, link_up=2)


def _shard_signals(sig: FleetSignals, mesh: jax.sharding.Mesh
                   ) -> FleetSignals:
    """Shard batched signals ``[R, T, …]``: replicas over the first mesh
    axis and (on a 2-D mesh) the edge axis over the second."""
    r = mesh.axis_names[0]
    e = mesh.axis_names[1] if len(mesh.axis_names) > 1 else None
    out = {}
    for f in FleetSignals._fields:
        a = getattr(sig, f)
        names = [None] * a.ndim
        names[0] = r
        ax = _SIGNAL_EDGE_AXIS[f]
        if e is not None and ax is not None:
            names[ax] = e
        out[f] = _put(a, mesh, tuple(names))
    return FleetSignals(**out)


# ---------------------------------------------------------------------------
# compiled fleet programs (cached: policy and profiles are runtime args,
# so a program is reused across every policy/scenario of the same shape)
# ---------------------------------------------------------------------------

# every live compiled program, for retrace accounting
# (repro.obs.prof.fleet_compile_stats): a program jit-traces once per
# input *shape* — policies are runtime data, so running more policies
# through it must add no traces (tests/conftest.py ``compile_guard``)
_PROGRAM_REGISTRY: list = []

# The program cache is bounded: the shape-bucketed sweep planner
# (:func:`plan_buckets`) deliberately keys one executable per bucket
# layout, and a long-lived process sweeping many bucket shapes must not
# accumulate jit wrappers (and their trace caches) without bound.  LRU
# order: the programs a sweep is actively cycling through stay resident;
# evicted programs also leave ``_PROGRAM_REGISTRY`` so retrace
# accounting tracks live executables only.
FLEET_PROGRAM_CACHE_CAPACITY = 32
_PROGRAM_CACHE: collections.OrderedDict = collections.OrderedDict()
_PROGRAM_EVICTIONS = 0


def _fleet_program(dt: float, edge_frac: float, cloud_frac: float,
                   coop_rounds: int, tspec: TraceSpec, batched: bool,
                   hetero: bool, donate: bool = False):
    """Jitted ``run(prof, pp, state, xs)``.

    ``batched`` adds a leading replica axis on the signals (and, when
    ``hetero``, on profiles/params/state too).  ``coop_rounds`` is the
    static peer-offload round bound (0 compiles cooperation out
    entirely); per-replica runtime caps mask rounds within it.
    ``tspec`` selects the flight-recorder streams tapped out of the scan;
    it is part of this cache's key, so the trace-off program is the very
    executable the untraced sweeps always compiled.  ``donate`` hands the
    ``state`` argument's buffers to XLA (``donate_argnums``): the carry
    is updated in place instead of round-tripping fresh allocations each
    chunk — callers must not reuse a donated input afterwards
    (:meth:`FleetProgram.run` copies the caller's initial state once).
    """
    global _PROGRAM_EVICTIONS
    key = (dt, edge_frac, cloud_frac, coop_rounds, tspec, batched, hetero,
           donate)
    prog = _PROGRAM_CACHE.get(key)
    if prog is not None:
        _PROGRAM_CACHE.move_to_end(key)
        return prog
    step = make_step(dt, edge_frac, cloud_frac, tspec)

    def run(prof, pp, state, xs):
        vstep = jax.vmap(step, in_axes=(
            None, None, 0, (None, 0, 0, 0, 0, 0, None, 0, 0, 0, 0)))

        def scan_body(state, xs_t):
            now = xs_t[0]
            valid = xs_t[7]
            edge_up = xs_t[9]
            state, tick = vstep(prof, pp, state, xs_t)
            if coop_rounds:
                pre_out, pre_in = state.n_peer_out, state.n_peer_in
                # crashed edges neither export nor import peer work
                state = peer_offload(
                    state, now + dt, pp.coop_slack_ms, coop_rounds,
                    enable=pp.cooperation,
                    transfer_cap=pp.coop_transfer_cap,
                    edge_valid=valid & edge_up)
                if tick is not None:
                    # the exchange runs on the stacked fleet state between
                    # ticks; fold its per-edge deltas into the tick row
                    tick = tick._replace(
                        peer_out=tick.peer_out + state.n_peer_out - pre_out,
                        peer_in=tick.peer_in + state.n_peer_in - pre_in)
            ys = (state.adapt.current if tspec.t_hat else None, tick)
            return state, ys

        final, (t_hat, counters) = jax.lax.scan(scan_body, state, xs)
        if tspec.enabled:
            return FleetResult(final, t_hat, counters)
        return final

    if batched:
        ax = 0 if hetero else None
        run = jax.vmap(run, in_axes=(ax, ax, ax, 0))
    prog = jax.jit(run, donate_argnums=(2,)) if donate else jax.jit(run)
    _PROGRAM_CACHE[key] = prog
    _PROGRAM_REGISTRY.append(prog)
    while len(_PROGRAM_CACHE) > FLEET_PROGRAM_CACHE_CAPACITY:
        _, evicted = _PROGRAM_CACHE.popitem(last=False)
        _PROGRAM_EVICTIONS += 1
        try:
            _PROGRAM_REGISTRY.remove(evicted)
        except ValueError:  # already dropped by reset_fleet_programs
            pass
    return prog


def _program_cache_clear() -> None:
    _PROGRAM_CACHE.clear()


# keep the lru_cache-era management surface: callers
# (benchmarks/bench_fleet.py, repro.obs.prof.reset_fleet_programs) clear
# the cache through the function object
_fleet_program.cache_clear = _program_cache_clear


def slice_signals(sig: FleetSignals, lo: int, hi: int, *,
                  tick_axis: int = 0) -> FleetSignals:
    """Ticks ``[lo, hi)`` of a signal tree as a window (``tick_axis=1``
    for batched ``[R, T, …]`` signals).  Every :class:`FleetSignals`
    field carries its tick axis in the same position, so a plain tree
    slice is a well-formed window."""
    idx = (slice(None),) * tick_axis + (slice(lo, hi),)
    return jax.tree.map(lambda a: a[idx], sig)


@dataclasses.dataclass(frozen=True)
class FleetProgram:
    """The compiled tick program as a *step-wise* control-plane API.

    ``init`` builds the stacked per-edge scheduler state;
    :meth:`step_chunk` advances it over one dt-aligned
    :class:`FleetSignals` window and returns the new state plus the
    window's flight-recorder streams.  Because each tick reads only the
    carried state and its own signal row, scanning a horizon in one call
    or chunk-by-chunk is the *same computation* — the replay entry
    points (:func:`run_fleet`, :func:`run_fleet_batch`,
    :func:`run_batch`) are thin :meth:`run` loops over ``step_chunk``
    with bitwise-identical results, and the online
    :class:`repro.serve.controller.FleetController` calls ``step_chunk``
    directly on telemetry-built windows.

    The jitted executable is shared through the :func:`_fleet_program`
    cache: two programs with equal static fields reuse one compile, and
    a chunk compiles once per distinct window length.

    ``donate=True`` compiles the executable with its state argument's
    buffers donated to XLA: the scan carry updates in place instead of
    allocating a fresh state every chunk — the steady-state win of the
    metropolis-scale path.  A donated :meth:`step_chunk` *consumes* the
    state you pass it (the input buffers are invalidated); :meth:`run`
    copies the caller's initial state once so replay callers can keep
    reusing their batches.
    """

    dt: float = 25.0
    edge_frac: float = 0.62
    cloud_frac: float = 0.80
    coop_rounds: int = 0
    trace: TraceSpec = TraceSpec()
    batched: bool = False
    hetero: bool = False
    donate: bool = False

    @classmethod
    def for_policy(cls, policy, *, trace: TraceSpec = TraceSpec(),
                   dt: float = 25.0, edge_frac: float = 0.62,
                   cloud_frac: float = 0.80, batched: bool = False,
                   hetero: bool = False, donate: bool = False
                   ) -> "FleetProgram":
        """A program whose static peer-offload bound matches ``policy``."""
        pol = _resolve_policy(policy)
        return cls(dt=dt, edge_frac=edge_frac, cloud_frac=cloud_frac,
                   coop_rounds=pol.coop_max_transfers if pol.cooperation
                   else 0, trace=trace, batched=batched, hetero=hetero,
                   donate=donate)

    def init(self, prof: Profiles, policy, n_edges: int,
             cloud_slots: int = CLOUD_SLOTS,
             total_slots: Optional[int] = None) -> EdgeState:
        """Fresh stacked fleet state (leading edge axis), exactly the
        state every replay entry point starts from."""
        pol = _resolve_policy(policy)
        return jax.vmap(
            lambda _: init_state(prof, pol.adapt_window, cloud_slots,
                                 total_slots=total_slots))(
            jnp.arange(n_edges))

    @property
    def _jitted(self):
        return _fleet_program(self.dt, self.edge_frac, self.cloud_frac,
                              self.coop_rounds, self.trace, self.batched,
                              self.hetero, self.donate)

    def step_chunk(self, prof: Profiles, pp: PolicyParams, state: EdgeState,
                   signals: FleetSignals):
        """Advance ``state`` over one signal window.

        Returns ``(state, result)`` — ``result`` is the window's
        :class:`FleetResult` (its trace streams cover only this window's
        ticks) when the program's :class:`~repro.obs.trace.TraceSpec` is
        enabled, else ``None``.  The call is bounded-latency: one jitted
        scan of ``window_ticks`` steps, no host round-trips inside.
        """
        out = self._jitted(prof, pp, state, tuple(signals))
        if self.trace.enabled:
            return out.final, out
        return out, None

    def run(self, prof: Profiles, pp: PolicyParams, state: EdgeState,
            signals: FleetSignals, chunk_ticks: Optional[int] = None):
        """Replay: loop :meth:`step_chunk` over the whole horizon.

        ``chunk_ticks=None`` runs the horizon as one chunk — the same
        single compiled call (and executable) the pre-refactor entry
        points made.  A finite ``chunk_ticks`` replays window-by-window,
        concatenating trace streams along the tick axis; results are
        bitwise identical either way.

        With ``donate`` on, the loop is *double-buffered*: the next
        window is sliced while the current chunk is still in flight
        (async dispatch overlaps host slicing with device compute) and
        the donated carry never round-trips a fresh allocation.  The
        caller's ``state`` buffers survive — the loop consumes a private
        copy.
        """
        tick_axis = 1 if self.batched else 0
        n_ticks = signals.times.shape[tick_axis]
        if self.donate:
            # the executable consumes its state input; replay callers
            # (e.g. a FleetBatch swept under several planners) keep
            # their initial state, so donate a copy instead
            state = jax.tree.map(jnp.copy, state)
        if chunk_ticks is None or chunk_ticks >= n_ticks:
            state, res = self.step_chunk(prof, pp, state, signals)
            return res if self.trace.enabled else state
        bounds = [(lo, min(lo + chunk_ticks, n_ticks))
                  for lo in range(0, n_ticks, chunk_ticks)]
        chunks = []
        win = slice_signals(signals, *bounds[0], tick_axis=tick_axis)
        for i in range(len(bounds)):
            nxt = slice_signals(signals, *bounds[i + 1],
                                tick_axis=tick_axis) \
                if i + 1 < len(bounds) else None
            state, res = self.step_chunk(prof, pp, state, win)
            win = nxt
            chunks.append(res)
            if self.donate and (i & 7) == 7:
                # bound in-flight work: sync on the *newest* carry only
                # — older states are already donated away and their
                # buffers are dead
                jax.block_until_ready(state)
        if not self.trace.enabled:
            return state

        def cat(parts):
            if parts[0] is None:
                return None
            return jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=tick_axis), *parts)

        return FleetResult(state, cat([c.t_hat for c in chunks]),
                           cat([c.counters for c in chunks]))


def run_fleet(models: list[ModelProfile], policy, signals: FleetSignals, *,
              dt: float = 25.0, edge_frac: float = 0.62,
              cloud_frac: float = 0.80, cloud_slots: int = CLOUD_SLOTS,
              mesh: Optional[jax.sharding.Mesh] = None,
              record_trace: bool = False,
              trace: Optional[TraceSpec] = None,
              chunk_ticks: Optional[int] = None,
              donate: bool = False):
    """Run the fleet simulator over arbitrary scenario signals.

    ``policy`` is a :class:`FleetPolicy` or a name (``"DEMS"``,
    ``"GEMS-A-COOP"``, …).  ``cloud_slots`` is each edge's share of the
    bounded FaaS concurrency (the oracle's ``cloud_concurrency``); make it
    large to recover the elastic-cloud limit.  With ``mesh`` given, fleet
    state is sharded over its first axis (pjit-style data parallelism over
    edges); the peer offload exchange then runs as cross-device
    collectives.

    ``trace`` turns on the flight recorder: a
    :class:`~repro.obs.trace.TraceSpec` selecting the per-tick streams,
    returned as a :class:`FleetResult` (``t_hat`` shaped ``[T, E, M]``
    here; tracing never changes the scheduler's results — the final
    state is bit-identical to the untraced run).  ``record_trace=True``
    is the deprecated alias for ``TraceSpec(t_hat=True)``.  The default
    returns just the final :class:`EdgeState`.

    This is a thin :meth:`FleetProgram.run` loop; ``chunk_ticks``
    replays the horizon in windows of that many ticks (bitwise-identical
    to the default whole-horizon chunk — the streaming controller's
    execution path).  ``donate=True`` compiles the program with its
    state buffers donated (in-place carry updates, double-buffered
    windows) — same results bitwise, see :class:`FleetProgram`.
    """
    tspec = resolve_spec(trace, record_trace)
    pol = _resolve_policy(policy)
    prof = Profiles.build(models)
    n_edges = signals.arrive.shape[1]
    prog = FleetProgram.for_policy(pol, trace=tspec, dt=dt,
                                   edge_frac=edge_frac,
                                   cloud_frac=cloud_frac, donate=donate)
    state = prog.init(prof, pol, n_edges, cloud_slots)
    if mesh is not None:
        state = _shard_leading(state, mesh)
    return prog.run(prof, pol.params(), state, signals, chunk_ticks)


def stack_signals(signals: list[FleetSignals]) -> FleetSignals:
    """Stack per-run signals over a new leading replica axis.

    All runs must share (n_ticks, n_edges, n_models) — i.e. seeds or event
    variants of one scenario shape, the unit :func:`run_fleet_batch`
    compiles once and sweeps in a single program.  Heterogeneous shapes
    raise a :class:`ValueError` naming the offending field; use
    :func:`pad_signals` for a cross-scenario batch.
    """
    for f in FleetSignals._fields:
        shapes = [tuple(getattr(s, f).shape) for s in signals]
        if any(sh != shapes[0] for sh in shapes):
            raise ValueError(
                f"stack_signals: replica signals disagree on field {f!r} "
                f"(shapes {shapes}); stack only same-shape replicas "
                f"(seeds / event variants of one scenario) or use "
                f"pad_signals for a heterogeneous cross-scenario batch")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *signals)


def pad_signals(signals: list[FleetSignals],
                dt: float = 25.0) -> FleetSignals:
    """Mask heterogeneous per-run signals to the max shape and stack.

    Every replica is padded to the batch's max (ticks, edges, models):
    padded ticks/edges carry ``valid=False`` (the tick function reverts
    them to exact no-ops), padded models never arrive and their ids are
    appended to the insertion ``order`` so it stays a permutation.  The
    result feeds :func:`build_fleet_batch` / :func:`run_batch`, which run
    the whole cross-scenario sweep as one compiled program.
    """
    sigs = [jax.tree.map(np.asarray, s) for s in signals]
    tmax = max(s.arrive.shape[0] for s in sigs)
    emax = max(s.arrive.shape[1] for s in sigs)
    mmax = max(s.arrive.shape[2] for s in sigs)
    padded = []
    for s in sigs:
        t, e, m = s.arrive.shape
        pt, pe = tmax - t, emax - e
        step = float(s.times[1] - s.times[0]) if t > 1 else dt
        times = np.concatenate(
            [s.times, s.times[-1] + step * np.arange(1, pt + 1,
                                                     dtype=np.float32)])
        order = np.broadcast_to(np.arange(mmax, dtype=np.int32),
                                (tmax, emax, mmax)).copy()
        order[:t, :e, :m] = s.order
        valid = np.zeros((tmax, emax), dtype=bool)
        valid[:t, :e] = s.valid
        padded.append(FleetSignals(
            times=times.astype(np.float32),
            theta=np.pad(s.theta, ((0, pt), (0, pe))),
            bw=np.pad(s.bw, ((0, pt), (0, pe)),
                      constant_values=network.NOMINAL_BW_MBPS),
            arrive=np.pad(s.arrive, ((0, pt), (0, pe),
                                     (0, mmax - m))),
            order=order,
            load_mult=np.pad(s.load_mult, ((0, pt), (0, pe)),
                             constant_values=1.0),
            cloud_up=np.pad(s.cloud_up, (0, pt), constant_values=True),
            valid=valid,
            # padded cells keep the deterministic ×1.0 multiplier
            exec_jit=np.pad(s.exec_jit,
                            ((0, pt), (0, pe), (0, mmax - m), (0, 0)),
                            constant_values=1.0),
            # padded cells are healthy (valid=False already no-ops them)
            edge_up=np.pad(s.edge_up, ((0, pt), (0, pe)),
                           constant_values=True),
            link_up=np.pad(s.link_up, ((0, pt), (0, pe)),
                           constant_values=True)))
    return jax.tree.map(lambda *xs: jnp.stack([np.asarray(x)
                                               for x in xs]), *padded)


def run_fleet_batch(models: list[ModelProfile], policy,
                    signals: FleetSignals, *, dt: float = 25.0,
                    edge_frac: float = 0.62, cloud_frac: float = 0.80,
                    cloud_slots: int = CLOUD_SLOTS,
                    mesh: Optional[jax.sharding.Mesh] = None,
                    record_trace: bool = False,
                    trace: Optional[TraceSpec] = None,
                    donate: bool = False):
    """One-jit sweep: ``signals`` carry a leading replica axis ``[R, …]``
    (from :func:`stack_signals`), and the whole sweep — every replica's
    full mission scan — runs as a single ``vmap``-over-replicas compiled
    program instead of R sequential jits.

    Returns the stacked final :class:`EdgeState` with leading ``[R, E]``
    axes; slicing replica ``r`` reproduces ``run_fleet`` on that run's
    signals exactly.  With ``mesh`` given, replicas are sharded over its
    first axis; a 2-D mesh additionally shards the edge axis over its
    second (the (replica, edge) grid).  ``trace`` (or the deprecated
    ``record_trace`` alias for ``TraceSpec(t_hat=True)``) returns a
    :class:`FleetResult` instead, with replica-leading trace streams
    (``t_hat`` shaped ``[R, T, E, M]``).  For *heterogeneous* replicas
    (different scenarios / policies / pool depths) see
    :func:`build_fleet_batch` / :func:`run_batch`.
    """
    tspec = resolve_spec(trace, record_trace)
    pol = _resolve_policy(policy)
    prof = Profiles.build(models)
    n_edges = signals.arrive.shape[2]
    prog = FleetProgram.for_policy(pol, trace=tspec, dt=dt,
                                   edge_frac=edge_frac,
                                   cloud_frac=cloud_frac, batched=True,
                                   donate=donate)
    state = prog.init(prof, pol, n_edges, cloud_slots)
    if mesh is not None:
        # state is replica-shared (vmap in_axes None): leave it replicated
        # on a 1-D replica mesh; a 2-D mesh shards its edge axis over the
        # second mesh axis
        if len(mesh.axis_names) > 1:
            state = jax.tree.map(
                lambda a: _put(a, mesh, (mesh.axis_names[1],)), state)
        signals = _shard_signals(signals, mesh)
    return prog.run(prof, pol.params(), state, signals)


class FleetBatch(NamedTuple):
    """A heterogeneous sweep compiled to one program's inputs.

    ``profiles``/``params``/``state`` carry a leading replica axis
    matching ``signals``; ``coop_rounds`` is the static peer-offload
    bound (max across the batch's policies).
    """

    profiles: Profiles      # [R, Mp, …]
    params: PolicyParams    # [R]
    state: EdgeState        # [R, E, …]
    signals: FleetSignals   # [R, T, …]
    coop_rounds: int


def build_fleet_batch(runs, *, dt: float = 25.0) -> FleetBatch:
    """Assemble heterogeneous runs into one padded, stackable batch.

    ``runs`` is a list of ``(models, policy, signals, cloud_slots)``
    tuples — one per replica (scenario × policy × seed).  Model tables
    are padded to the max model count, pool arrays to the max slot
    count, signals to the max (ticks, edges) shape; policies become
    per-replica runtime :class:`PolicyParams`.  Policies must agree on
    ``adapt_window`` (an estimator buffer *shape*).
    """
    pols = [_resolve_policy(p) for _, p, _, _ in runs]
    windows = {p.adapt_window for p in pols}
    if len(windows) > 1:
        raise ValueError(
            f"build_fleet_batch: policies disagree on adapt_window "
            f"{sorted(windows)} — the estimator buffer is a compiled "
            f"shape, so one batch must share it")
    mmax = max(len(models) for models, _, _, _ in runs)
    smax = max(slots for _, _, _, slots in runs)
    emax = max(sig.arrive.shape[1] for _, _, sig, _ in runs)
    profs, states, cache = [], [], {}
    for (models, _, sig, slots), pol in zip(runs, pols):
        # lanes of the same (model table, pool, window) share one init
        # (ModelProfile is a frozen dataclass, so the full table is the key)
        key = (slots, pol.adapt_window, tuple(models))
        if key not in cache:
            prof = Profiles.build(models, pad_to=mmax)
            cache[key] = (prof, jax.vmap(
                lambda _, prof=prof: init_state(
                    prof, pol.adapt_window, slots, total_slots=smax))(
                jnp.arange(emax)))
        prof, state = cache[key]
        profs.append(prof)
        states.append(state)
    return FleetBatch(
        profiles=jax.tree.map(lambda *xs: jnp.stack(xs), *profs),
        params=jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[p.params() for p in pols]),
        state=jax.tree.map(lambda *xs: jnp.stack(xs), *states),
        signals=pad_signals([sig for _, _, sig, _ in runs], dt),
        coop_rounds=max((p.coop_max_transfers for p in pols
                         if p.cooperation), default=0))


def plan_buckets(runs, *, dt: float = 25.0
                 ) -> list[tuple[FleetBatch, tuple[int, ...]]]:
    """Shape-bucketed planner: exact-shape batches, one jit per bucket.

    Takes the same ``(models, policy, signals, cloud_slots)`` run list
    as :func:`build_fleet_batch`, but instead of padding every replica
    to the batch max shape, partitions the runs by exact
    ``(ticks, edges, models, coop_rounds, adapt_window)`` — within a
    bucket stacking is exact, so mixed-size sweeps (the ``*-COOP``
    registry case) stop paying max-shape padding, and peer-offload
    rounds compile only into the buckets that need them.  Each bucket
    compiles one program; the bounded :func:`_fleet_program` cache keeps
    bucket proliferation from retrace-leaking.

    Returns ``(batch, idxs)`` per bucket, where ``idxs`` maps the
    bucket's replica lanes back to positions in ``runs`` (lane ``k`` of
    the bucket's :func:`run_batch` result is run ``idxs[k]``).  Bucket
    results are bitwise identical to running the whole list through one
    padded :func:`build_fleet_batch` / :func:`run_batch` program —
    padding cells are exact no-ops by construction, so both equal the
    per-run :func:`run_fleet` loop.
    """
    buckets: dict = {}
    for i, run in enumerate(runs):
        models, policy, sig, _slots = run
        pol = _resolve_policy(policy)
        t, e, _m = sig.arrive.shape
        key = (t, e, len(models),
               pol.coop_max_transfers if pol.cooperation else 0,
               pol.adapt_window)
        bucket = buckets.setdefault(key, ([], []))
        bucket[0].append(run)
        bucket[1].append(i)
    return [(build_fleet_batch(rs, dt=dt), tuple(idxs))
            for rs, idxs in buckets.values()]


def run_batch(batch: FleetBatch, *, dt: float = 25.0,
              edge_frac: float = 0.62, cloud_frac: float = 0.80,
              mesh: Optional[jax.sharding.Mesh] = None,
              record_trace: bool = False,
              trace: Optional[TraceSpec] = None,
              donate: bool = False,
              chunk_ticks: Optional[int] = None):
    """Execute a heterogeneous :class:`FleetBatch` as one compiled program.

    Every replica — its own scenario shape, policy flags, model table and
    pool depth — runs under one jit; per-replica slices of the returned
    ``[R, E, …]`` state match the corresponding :func:`run_fleet` call
    exactly (padding is a no-op by construction).  A 2-D ``mesh`` shards
    the (replica, edge) grid; a 1-D mesh shards replicas only.  ``trace``
    (or the deprecated ``record_trace`` alias) returns a
    :class:`FleetResult` whose streams lead with the replica axis
    (``t_hat`` shaped ``[R, T, E, M]``); padded (tick, edge) cells record
    zero events, by the same masking that makes them state no-ops.
    ``donate=True`` hands the batch's state buffers to XLA for in-place
    carry updates (``batch.state`` itself stays valid — the program runs
    on a private copy); ``chunk_ticks`` replays the horizon in
    double-buffered windows.  Both knobs leave results bitwise unchanged.
    """
    tspec = resolve_spec(trace, record_trace)
    prof, pp, state, sig = (batch.profiles, batch.params, batch.state,
                            batch.signals)
    prog = FleetProgram(dt=dt, edge_frac=edge_frac, cloud_frac=cloud_frac,
                        coop_rounds=batch.coop_rounds, trace=tspec,
                        batched=True, hetero=True, donate=donate)
    if mesh is not None:
        prof = _shard_leading(prof, mesh, axes=1)
        pp = _shard_leading(pp, mesh, axes=1)
        state = _shard_leading(state, mesh, axes=2)
        sig = _shard_signals(sig, mesh)
    return prog.run(prof, pp, state, sig, chunk_ticks)


def simulate_fleet(models: list[ModelProfile], policy: str, *,
                   n_edges: int, drones_per_edge: int = 3,
                   duration_ms: float = 300_000.0, dt: float = 25.0,
                   edge_frac: float = 0.62, cloud_frac: float = 0.80,
                   cloud_slots: int = CLOUD_SLOTS,
                   theta_fn=None, bw_fn=None, seed: int = 0,
                   mesh: Optional[jax.sharding.Mesh] = None) -> EdgeState:
    """Simulate ``n_edges`` base stations under the paper's steady
    workload; returns stacked final states.  Scenario-driven runs (bursts,
    mobility, outages, …) go through :func:`run_fleet` with signals from
    :mod:`repro.scenarios.compile`."""
    signals = default_signals(len(models), n_edges=n_edges,
                              drones_per_edge=drones_per_edge,
                              duration_ms=duration_ms, dt=dt,
                              theta_fn=theta_fn, bw_fn=bw_fn, seed=seed)
    return run_fleet(models, policy, signals, dt=dt, edge_frac=edge_frac,
                     cloud_frac=cloud_frac, cloud_slots=cloud_slots,
                     mesh=mesh)
