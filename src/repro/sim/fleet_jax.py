"""Fleet-scale SPMD scheduler simulation (paper §8.6, TPU-native).

The paper weak-scales its platform to 84 drones / 28 edges by replicating
containers.  Here the *entire fleet* is one JAX program: per-edge scheduler
state is a PyTree of arrays with a leading ``fleet`` axis, each tick applies
the decision kernels of :mod:`repro.core.jax_sched` under ``vmap``, and the
fleet axis is sharded across devices with ``NamedSharding`` — the same
program scales from 1 edge on CPU to 10⁵ edges on a pod.

Modeling simplifications vs the event-driven oracle (documented per §Design):

* fixed time step ``dt`` (default 25 ms) instead of an event heap;
* deterministic execution fractions (edge ``edge_frac·t``, cloud
  ``cloud_frac·t̂ + θ(t) + bw-penalty``) — variability enters via the
  shaped θ trace and the dense cellular-bandwidth signal ``bw`` (the
  signed transfer penalty convention of
  :meth:`repro.sim.network.CloudLatencyModel.shaped_delta`);
* the cloud is a **finite pool**: each edge owns ``cloud_slots``
  busy-until slots (its share of the bounded FaaS concurrency, mirroring
  the oracle's per-edge ``cloud_concurrency``).  A matured task only
  dispatches when a slot is free; while the pool is saturated it stays
  parked on the trigger-time queue (still stealable) and the estimated
  queue-wait ``max(0, min(busy_until) − now)`` is folded into the t̂ used
  by routing, migration, stealing triggers and GEMS feasibility.  With a
  large pool the wait is identically zero and the elastic model is
  recovered exactly;
* tasks matured in the same tick dispatch in queue-slot order (the oracle
  pops in trigger order) — indistinguishable in the elastic limit, an
  approximation under saturation;
* DEMS-A observations are batched per tick (the oracle interleaves
  estimator updates in event order within one instant).

Supported policy flags: EDF-E+C routing, DEM migration, DEMS work stealing
with trigger-time cloud queue and steal-only parking, DEMS-A sliding-window
cloud-latency adaptation (§5.4), GEMS window rescheduling.
``tests/test_fleet_jax.py`` checks single-edge agreement with the
discrete-event engine.

Sweeps (seeds × scenario variants) run as *one* compiled program through
:func:`run_fleet_batch`: stack per-run :class:`FleetSignals` with
:func:`stack_signals` and the whole sweep becomes a single
``vmap``-over-replicas jitted scan, optionally sharded over a mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_sched as js
from repro.core import schedulers as _sched
from repro.core.task import ModelProfile
from repro.sim import network

EDGE_CAP = 32
CLOUD_CAP = 64
SUBSTEPS = 6      # max edge executor actions (drops/starts) per tick
CLOUD_SLOTS = 16  # default per-edge FaaS share (engine's cloud_concurrency)


# Fleet-supported policy names; flag sets derive from the oracle's registry
# (core.schedulers._POLICIES) so the two simulators cannot drift apart.
_FLEET_POLICY_NAMES = ("EDF", "EDF-E+C", "DEM", "DEMS", "DEMS-A", "GEMS",
                       "GEMS-A")
_FLEET_FLAGS = ("migration", "stealing", "gems", "adaptive", "use_cloud")
_FLEET_POLICIES = {
    name: {k: v for k, v in _sched._POLICIES[name].items()
           if k in _FLEET_FLAGS}
    for name in _FLEET_POLICY_NAMES
}


@dataclasses.dataclass(frozen=True)
class FleetPolicy:
    """Trace-time policy flags (subset of core.schedulers.Policy)."""

    migration: bool = False
    stealing: bool = False
    gems: bool = False
    use_cloud: bool = True
    cloud_margin: float = 50.0
    # DEMS-A sliding-window cloud-latency adaptation (§5.4): estimator
    # hyper-parameters mirror core.schedulers.AdaptiveEstimator.
    adaptive: bool = False
    adapt_window: int = 10
    adapt_eps: float = 10.0
    adapt_cooling_ms: float = 10_000.0
    # cross-edge cooperation (beyond-paper; fleet-scope work stealing):
    # after each tick, edges whose minimum queue slack drops below
    # ``coop_slack_ms`` export their worst-slack feasible tasks to the
    # least-loaded peer, at most ``coop_max_transfers`` moves per tick.
    cooperation: bool = False
    coop_slack_ms: float = 0.0
    coop_max_transfers: int = 2

    @classmethod
    def from_name(cls, name: str) -> "FleetPolicy":
        coop = name.endswith("-COOP")
        base_name = name[: -len("-COOP")] if coop else name
        if base_name not in _FLEET_POLICIES:
            supported = sorted(_FLEET_POLICIES) + sorted(
                n + "-COOP" for n in _FLEET_POLICIES)
            raise ValueError(f"unknown fleet policy {name!r}; choose from "
                             f"{supported}")
        base = cls(**_FLEET_POLICIES[base_name])
        return dataclasses.replace(base, cooperation=True) if coop else base


class Profiles(NamedTuple):
    """Array-of-struct model table (M models)."""

    t_edge: jax.Array
    t_cloud: jax.Array
    deadline: jax.Array
    gamma_e: jax.Array
    gamma_c: jax.Array
    cost_e: jax.Array
    cost_c: jax.Array
    steal_rank: jax.Array
    qoe_alpha: jax.Array
    qoe_beta: jax.Array
    qoe_window: jax.Array

    @classmethod
    def build(cls, models: list[ModelProfile]) -> "Profiles":
        f = jnp.asarray
        return cls(
            t_edge=f([m.t_edge for m in models], jnp.float32),
            t_cloud=f([m.t_cloud for m in models], jnp.float32),
            deadline=f([m.deadline for m in models], jnp.float32),
            gamma_e=f([m.gamma_edge for m in models], jnp.float32),
            gamma_c=f([m.gamma_cloud for m in models], jnp.float32),
            cost_e=f([m.cost_edge for m in models], jnp.float32),
            cost_c=f([m.cost_cloud for m in models], jnp.float32),
            steal_rank=f([m.steal_rank() for m in models], jnp.float32),
            qoe_alpha=f([m.qoe_alpha for m in models], jnp.float32),
            qoe_beta=f([m.qoe_beta for m in models], jnp.float32),
            qoe_window=f([m.qoe_window for m in models], jnp.float32),
        )


class EdgeState(NamedTuple):
    """Per-edge scheduler state (leading fleet axis added by vmap)."""

    eq: js.EdgeQueue
    cq: js.CloudQueue
    cq_model: jax.Array        # i32[Qc] model ids of cloud-queued tasks
    busy_rem: jax.Array        # f32[] remaining edge execution time
    # finite FaaS pool: busy-until time per cloud slot (this edge's share
    # of the bounded Lambda concurrency; slot free iff busy_until <= now)
    cloud_busy_until: jax.Array  # f32[S]
    # cloud-queue entries that have waited for a saturated pool at least
    # once: when their slot finally frees they re-run the oracle's
    # dispatch-time JIT check (never set in the elastic limit)
    cq_blocked: jax.Array      # bool[Qc]
    seq: jax.Array             # i32[] insertion counter
    # stats
    n_success: jax.Array       # i32[M]
    n_miss: jax.Array          # i32[M]
    n_drop: jax.Array          # i32[M]
    n_stolen: jax.Array        # i32[M]
    n_edge_exec: jax.Array     # i32[M] tasks executed on the edge
    qos_utility: jax.Array     # f32[]
    # GEMS window state
    lam: jax.Array             # i32[M]
    lam_hat: jax.Array         # i32[M]
    win_end: jax.Array         # f32[M]
    qoe_utility: jax.Array     # f32[]
    windows_met: jax.Array     # i32[M]
    # cross-edge cooperation stats
    n_peer_out: jax.Array      # i32[] tasks exported to a peer edge
    n_peer_in: jax.Array       # i32[] tasks imported from a peer edge
    # DEMS-A estimator state (§5.4): per-model sliding-window t̂
    adapt: js.AdaptState


def init_state(prof: Profiles, adapt_window: int = 10,
               cloud_slots: int = CLOUD_SLOTS) -> EdgeState:
    m = prof.t_edge.shape[0]
    zi = jnp.zeros(m, jnp.int32)
    return EdgeState(
        eq=js.empty_edge_queue(EDGE_CAP), cq=js.empty_cloud_queue(CLOUD_CAP),
        cq_model=jnp.zeros(CLOUD_CAP, jnp.int32),
        busy_rem=jnp.zeros(()),
        cloud_busy_until=jnp.zeros(cloud_slots),
        cq_blocked=jnp.zeros(CLOUD_CAP, bool),
        seq=jnp.zeros((), jnp.int32),
        n_success=zi, n_miss=zi, n_drop=zi, n_stolen=zi, n_edge_exec=zi,
        qos_utility=jnp.zeros(()),
        lam=zi, lam_hat=zi, win_end=prof.qoe_window,
        qoe_utility=jnp.zeros(()), windows_met=zi,
        n_peer_out=jnp.zeros((), jnp.int32),
        n_peer_in=jnp.zeros((), jnp.int32),
        adapt=js.adapt_init(prof.t_cloud, adapt_window))


def _pool_wait(st: EdgeState, now) -> jax.Array:
    """Estimated queue-wait until a cloud slot frees; 0 when one is free."""
    return jnp.maximum(st.cloud_busy_until.min() - now, 0.0)


def _free_slot_gate(busy_until: jax.Array, now,
                    want: jax.Array) -> jax.Array:
    """Admit the first ``n_free`` wanting tasks, in slot order.

    ``want`` marks queue entries that would each occupy one cloud slot;
    the gate is True for those that find a free slot this tick (tasks
    popped-and-dropped without dispatching never consume a slot, so they
    are gated by the same dispatch count — as in the oracle's pop loop).
    """
    wi = want.astype(jnp.int32)
    taken_before = jnp.cumsum(wi) - wi          # exclusive dispatch count
    return taken_before < (busy_until <= now).sum()


def _occupy_slots(busy_until: jax.Array, now, dispatch: jax.Array,
                  end_time: jax.Array) -> jax.Array:
    """Assign each dispatched task a distinct free slot, vectorized.

    Dispatched task k (in queue order) fills the k-th free slot with its
    completion time; ``dispatch`` must already be gated by
    :func:`_free_slot_gate` so ranks never exceed the free count.
    """
    s = busy_until.shape[0]
    di = dispatch.astype(jnp.int32)
    drank = jnp.cumsum(di) - di
    end_by_rank = jnp.zeros(s).at[
        jnp.where(dispatch, drank, s)].set(end_time, mode="drop")
    free = busy_until <= now
    fi = free.astype(jnp.int32)
    frank = jnp.cumsum(fi) - fi
    fill = free & (frank < dispatch.sum())
    return jnp.where(fill, end_by_rank[frank], busy_until)


def _t_cloud_cur(st: EdgeState, prof: Profiles, pol: FleetPolicy,
                 now) -> jax.Array:
    """Scheduler's current cloud-latency estimate t̂ per model (§5.4),
    plus the finite-pool queue-wait estimate (zero while slots are free),
    so routing, migration, stealing triggers and GEMS feasibility all see
    the congested cloud."""
    base = st.adapt.current if pol.adaptive else prof.t_cloud
    return base + _pool_wait(st, now)


class FleetSignals(NamedTuple):
    """Dense per-tick scenario signals driving the fleet simulator.

    Produced either by :func:`default_signals` (the paper's steady
    3-drones-per-edge workload) or by
    :func:`repro.scenarios.compile.compile_fleet` (mobility, handover,
    bursts, churn, outages, heterogeneous edges).
    """

    times: jax.Array       # f32[T]    tick start times [ms]
    theta: jax.Array       # f32[T,E]  per-edge added WAN latency θ(t)
    bw: jax.Array          # f32[T,E]  per-edge cellular bandwidth [Mbps]
    arrive: jax.Array      # bool[T,E,M] model m arrives at edge e this tick
    order: jax.Array       # i32[T,E,M] randomized insertion order (§3.3)
    load_mult: jax.Array   # f32[T,E]  edge execution-time multiplier
    cloud_up: jax.Array    # bool[T]   cloud FaaS availability


# ---------------------------------------------------------------------------
# per-tick logic for one edge
# ---------------------------------------------------------------------------

def _resolve_cloud(st: EdgeState, prof: Profiles, now, theta, bw_pen,
                   cloud_frac, pol: FleetPolicy, cloud_up) -> EdgeState:
    """Dispatch matured cloud tasks into the finite FaaS pool.

    During a cloud outage (``cloud_up`` False) matured tasks stay parked
    on the trigger-time queue; the dispatch-time deadline check settles
    their fate once the cloud returns — mirroring the oracle's behavior.
    Likewise, while the slot pool is saturated, matured tasks stay parked
    (still stealable, like the oracle's ``cloud_pending``) and retry once
    a slot frees; a dispatched task occupies its slot for the whole
    actual duration ``cloud_frac·t̂ + θ(t) + bw-penalty``.

    With ``pol.adaptive`` (DEMS-A, §5.4) dispatch adds the oracle's JIT
    check against the *adapted* estimate t̂: tasks it predicts to miss are
    skipped (dropped, feeding the cooling timer) instead of dispatched —
    without consuming a slot; dispatched tasks fire ``on_sent`` and
    ``observe`` their actual duration.
    """
    mature = st.cq.valid & (st.cq.trigger <= now) & cloud_up
    run = mature & ~st.cq.steal_only
    if pol.adaptive:
        est = st.adapt.current[st.cq_model]
        fits = now + est <= st.cq.deadline
    else:
        # the oracle JIT-checks every pop against the static estimate; in
        # the fleet model tasks normally mature within one tick of their
        # feasibility-checked trigger, so the check is redundant — except
        # for tasks that sat out a saturated pool, which re-run it here
        # (never taken in the elastic limit).  Outage-parked tasks keep
        # the documented modeling simplification of settling via the
        # dispatch-time deadline check instead (the oracle JIT-drops them
        # at recovery without consuming a slot); under a small pool the
        # difference is bounded to one pool-depth of doomed dispatches,
        # since everything behind them fails the slot gate, turns
        # cq_blocked, and does re-run this check.
        fits = ~st.cq_blocked | (now + prof.t_cloud[st.cq_model]
                                 <= st.cq.deadline)
    avail = _free_slot_gate(st.cloud_busy_until, now, run & fits)
    dispatch = run & fits & avail
    skipped = run & ~fits & avail     # popped + JIT-dropped, slot stays free
    act = cloud_frac * prof.t_cloud[st.cq_model] + theta + bw_pen
    success = dispatch & (now + act <= st.cq.deadline)
    util = jnp.where(success, prof.gamma_c[st.cq_model],
                     jnp.where(dispatch, -prof.cost_c[st.cq_model],
                               0.0)).sum()
    add = functools.partial(jax.ops.segment_sum, num_segments=prof.t_edge.shape[0])
    n_success = st.n_success + add(success.astype(jnp.int32), st.cq_model)
    n_miss = st.n_miss + add((dispatch & ~success).astype(jnp.int32),
                             st.cq_model)
    dropped = mature & st.cq.steal_only      # not stolen in time (§5.3)
    n_drop = st.n_drop + add((dropped | skipped).astype(jnp.int32),
                             st.cq_model)
    settled = dispatch | skipped | dropped   # blocked tasks stay parked
    new_valid = st.cq.valid & ~settled
    st = st._replace(cq=st.cq._replace(valid=new_valid),
                     cloud_busy_until=_occupy_slots(
                         st.cloud_busy_until, now, dispatch, now + act),
                     cq_blocked=(st.cq_blocked | (run & ~avail)) & new_valid,
                     n_success=n_success, n_miss=n_miss, n_drop=n_drop,
                     qos_utility=st.qos_utility + util)
    if pol.adaptive:
        def feed(i, ad):
            m = st.cq_model[i]
            sent = js.adapt_observe(js.adapt_on_sent(ad, m), m, act[i],
                                    pol.adapt_eps)
            ad = js.adapt_select(dispatch[i], sent, ad)
            skip = js.adapt_on_skip(ad, m, now, prof.t_cloud,
                                    pol.adapt_cooling_ms)
            return js.adapt_select(skipped[i], skip, ad)
        st = st._replace(adapt=jax.lax.fori_loop(0, CLOUD_CAP, feed,
                                                 st.adapt))
    if pol.gems:
        st = _gems_bulk(st, prof, now, success, dispatch | skipped | dropped,
                        st.cq_model)
    return st


def _gems_bulk(st: EdgeState, prof: Profiles, now, success_mask, done_mask,
               model_ids) -> EdgeState:
    """Window counters for a batch of task completions/drops."""
    m = prof.t_edge.shape[0]
    add = functools.partial(jax.ops.segment_sum, num_segments=m)
    lam = st.lam + add(done_mask.astype(jnp.int32), model_ids)
    lam_hat = st.lam_hat + add(success_mask.astype(jnp.int32), model_ids)
    return st._replace(lam=lam, lam_hat=lam_hat)


def _gems_act(st: EdgeState, prof: Profiles, now, theta, bw_pen, cloud_frac,
              pol: FleetPolicy) -> EdgeState:
    """Alg. 1: reschedule lagging models, close expired windows.

    Rescheduled tasks go through the same finite pool as the dispatch
    path: the feasibility gate sees the queue-wait-folded t̂, moves are
    capped by the free slots this tick (the rest stay on the edge queue
    and may move next tick if still lagging), and each move occupies a
    slot for the actual-duration model ``cloud_frac·t̂ + θ + bw-penalty``.

    Plain GEMS keeps the legacy modeling simplification of resolving the
    move's *outcome* at the deterministic estimate t̂ (no shaping) — the
    elastic-limit behavior this refactor preserves bit-for-bit; only
    GEMS-A resolves at the actual-duration model and feeds completions to
    the estimator (mirroring the oracle, where rescheduled tasks go
    through the instrumented cloud dispatch path).
    """
    m = prof.t_edge.shape[0]
    rate = st.lam_hat / jnp.maximum(st.lam, 1)
    lagging = (st.lam > 0) & (rate < prof.qoe_alpha)

    # move pending edge tasks of lagging models to the cloud (trigger=now,
    # resolved immediately into the free slots of the finite pool).
    t_hat = _t_cloud_cur(st, prof, pol, now)
    feas = now + t_hat[st.eq.model] <= st.eq.deadline
    want = (st.eq.valid & lagging[st.eq.model]
            & (prof.gamma_c[st.eq.model] > 0) & feas)
    move = want & _free_slot_gate(st.cloud_busy_until, now, want)
    # slots are *held* for the actual duration either way; only the
    # outcome model differs between GEMS (estimate) and GEMS-A (actual)
    hold = cloud_frac * prof.t_cloud[st.eq.model] + theta + bw_pen
    act = prof.t_cloud[st.eq.model]          # deterministic estimate
    if pol.adaptive:
        act = hold
    success = move & (now + act <= st.eq.deadline)
    add = functools.partial(jax.ops.segment_sum, num_segments=m)
    util = jnp.where(success, prof.gamma_c[st.eq.model],
                     jnp.where(move, -prof.cost_c[st.eq.model], 0.0)).sum()
    if pol.adaptive:
        eq_model = st.eq.model
        def feed(i, ad):
            mi = eq_model[i]
            sent = js.adapt_observe(js.adapt_on_sent(ad, mi), mi, act[i],
                                    pol.adapt_eps)
            return js.adapt_select(move[i], sent, ad)
        st = st._replace(adapt=jax.lax.fori_loop(0, EDGE_CAP, feed,
                                                 st.adapt))
    st = st._replace(
        eq=js.edge_remove(st.eq, move),
        cloud_busy_until=_occupy_slots(st.cloud_busy_until, now, move,
                                       now + hold),
        n_success=st.n_success + add(success.astype(jnp.int32), st.eq.model),
        n_miss=st.n_miss + add((move & ~success).astype(jnp.int32),
                               st.eq.model),
        qos_utility=st.qos_utility + util)
    st = _gems_bulk(st, prof, now, success, move, st.eq.model)

    # tumbling-window close (Eqn 2)
    expired = now > st.win_end
    met = expired & (st.lam > 0) & (st.lam_hat / jnp.maximum(st.lam, 1)
                                    >= prof.qoe_alpha)
    qoe = jnp.where(met, prof.qoe_beta, 0.0).sum()
    return st._replace(
        lam=jnp.where(expired, 0, st.lam),
        lam_hat=jnp.where(expired, 0, st.lam_hat),
        win_end=jnp.where(expired, st.win_end + prof.qoe_window, st.win_end),
        qoe_utility=st.qoe_utility + qoe,
        windows_met=st.windows_met + met.astype(jnp.int32))


def _offer_cloud(st: EdgeState, prof: Profiles, now, model, deadline, te,
                 pol: FleetPolicy, enable) -> tuple[EdgeState, jax.Array]:
    """Cloud admission (Policy.offer_cloud) — returns (state, accepted).

    ``te`` is the task's *effective* edge latency on this edge (speed
    factor folded in), kept on the cloud queue for steal decisions.

    Feasibility and trigger times use the DEMS-A-adapted t̂ when the
    policy is adaptive — plus the finite-pool queue-wait estimate, so a
    congested cloud pulls stealing triggers earlier and fails the
    feasibility gate sooner; a policy-level rejection then counts as a
    *skip* for the estimator's cooling logic (oracle ``_offer_cloud``).
    """
    if not pol.use_cloud:
        return st, jnp.asarray(False)
    t_hat = _t_cloud_cur(st, prof, pol, now)[model]
    feasible = now + t_hat <= deadline
    negative = prof.gamma_c[model] <= 0
    if pol.stealing:
        trigger = jnp.where(negative, deadline - te,
                            jnp.maximum(now, deadline - t_hat
                                        - pol.cloud_margin))
        ok_neg = trigger >= now
        accept = enable & feasible & jnp.where(negative, ok_neg, True)
        steal_only = negative
    else:
        trigger = now
        accept = enable & feasible & ~negative
        steal_only = jnp.asarray(False)
    cq, pushed = js.cloud_push(st.cq, trigger, te, deadline,
                               steal_only, prof.steal_rank[model],
                               enable=accept)
    slot = jnp.argmax(~st.cq.valid)
    cq_model = jnp.where(pushed, st.cq_model.at[slot].set(model),
                         st.cq_model)
    cq_blocked = jnp.where(pushed, st.cq_blocked.at[slot].set(False),
                           st.cq_blocked)
    st = st._replace(cq=cq, cq_model=cq_model, cq_blocked=cq_blocked)
    if pol.adaptive:
        skip = js.adapt_on_skip(st.adapt, model, now, prof.t_cloud,
                                pol.adapt_cooling_ms)
        st = st._replace(adapt=js.adapt_select(enable & ~accept, skip,
                                               st.adapt))
    return st, pushed


def _route_arrival(st: EdgeState, prof: Profiles, now, model,
                   pol: FleetPolicy, arrive, load_mult) -> EdgeState:
    """Task-scheduler routing for one arriving task (§5.1–5.2).

    ``load_mult`` is the edge's speed factor: the effective edge latency
    ``load_mult·t_edge`` is stored on the queues, so feasibility, JIT
    checks, stealing and execution all see the heterogeneous speed —
    matching the oracle compiler, which folds it into the model table.
    """
    deadline = now + prof.deadline[model]
    te = prof.t_edge[model] * load_mult
    feasible = js.insert_feasible(st.eq, now, st.busy_rem, deadline, te,
                                  deadline)
    if pol.migration:
        victims = js.victim_mask(st.eq, now, st.busy_rem, deadline, te)
        migrate_ok = js.migration_decision(
            st.eq, victims, now, model, deadline, prof.gamma_e,
            prof.gamma_c, _t_cloud_cur(st, prof, pol, now))
        has_victims = victims.any()
        insert_edge = arrive & feasible & (~has_victims | migrate_ok)

        # migrate victims: offer each to the cloud, then drop the rejects.
        # (victims / model / deadline read from the pre-loop queue; the loop
        # only mutates the cloud queue and drop counters)
        def offer_victim(i, s):
            is_v = victims[i] & insert_edge
            s2, pushed = _offer_cloud(s, prof, now, st.eq.model[i],
                                      st.eq.deadline[i], st.eq.t_edge[i],
                                      pol, is_v)
            rejected = is_v & ~pushed
            return s2._replace(n_drop=s2.n_drop.at[st.eq.model[i]].add(
                rejected.astype(jnp.int32)))
        st = jax.lax.fori_loop(0, EDGE_CAP, offer_victim, st)
        st = st._replace(eq=js.edge_remove(st.eq, victims & insert_edge))
    else:
        insert_edge = arrive & feasible

    eq, _ = js.edge_push(st.eq, deadline, st.seq, te, deadline, model,
                         enable=insert_edge)
    st = st._replace(eq=eq, seq=st.seq + arrive.astype(jnp.int32))
    to_cloud = arrive & ~insert_edge
    st, pushed = _offer_cloud(st, prof, now, model, deadline, te, pol,
                              to_cloud)
    st = st._replace(n_drop=st.n_drop.at[model].add(
        (to_cloud & ~pushed).astype(jnp.int32)))
    return st


def _edge_execute(st: EdgeState, prof: Profiles, now, dt, edge_frac,
                  pol: FleetPolicy, min_edge_t) -> EdgeState:
    """Edge executor: JIT drops, stealing, starting the next task.

    Queue entries carry the *effective* edge latency (speed factor folded
    in at insert time), so every check and the executed duration reflect
    heterogeneous edge speeds consistently.
    """
    def body(_, s: EdgeState) -> EdgeState:
        idle = s.busy_rem <= 0.0

        # JIT check on the head
        eq_after, head_idx, found = js.edge_pop_head(s.eq)
        head_model = s.eq.model[head_idx]
        head_dl = s.eq.deadline[head_idx]
        head_te = s.eq.t_edge[head_idx]
        head_infeasible = found & (now + head_te > head_dl)
        do_drop = idle & head_infeasible
        s = s._replace(
            eq=jax.tree.map(lambda a, b: jnp.where(do_drop, a, b),
                            eq_after, s.eq),
            n_drop=s.n_drop.at[head_model].add(do_drop.astype(jnp.int32)))
        if pol.gems:
            m_ids = jnp.arange(prof.t_edge.shape[0], dtype=jnp.int32)
            s = _gems_bulk(s, prof, now, jnp.zeros_like(m_ids, bool),
                           (m_ids == head_model) & do_drop, m_ids)

        idle = idle & ~head_infeasible
        # stealing (§5.3)
        if pol.stealing:
            sidx = js.steal_select(s.cq, s.eq, now, jnp.maximum(s.busy_rem,
                                                                0.0),
                                   min_edge_t)
            can_steal = idle & (sidx >= 0)
            smodel = s.cq_model[jnp.maximum(sidx, 0)]
            sdl = s.cq.deadline[jnp.maximum(sidx, 0)]
            ste = s.cq.t_edge[jnp.maximum(sidx, 0)]
            s = s._replace(cq=s.cq._replace(
                valid=jnp.where(can_steal,
                                s.cq.valid.at[jnp.maximum(sidx, 0)].set(
                                    False), s.cq.valid)),
                n_stolen=s.n_stolen.at[smodel].add(
                    can_steal.astype(jnp.int32)))
        else:
            can_steal = jnp.asarray(False)
            smodel = jnp.zeros((), jnp.int32)
            sdl = jnp.zeros(())
            ste = jnp.zeros(())

        # start next task: stolen task first, else the queue head
        eq_after, head_idx, found = js.edge_pop_head(s.eq)
        start_head = idle & ~can_steal & found
        run_model = jnp.where(can_steal, smodel, s.eq.model[head_idx])
        run_dl = jnp.where(can_steal, sdl, s.eq.deadline[head_idx])
        run_te = jnp.where(can_steal, ste, s.eq.t_edge[head_idx])
        start = can_steal | start_head
        act = edge_frac * run_te
        success = start & (now + act <= run_dl)
        util = jnp.where(success, prof.gamma_e[run_model],
                         jnp.where(start, -prof.cost_e[run_model], 0.0))
        s = s._replace(
            eq=jax.tree.map(lambda a, b: jnp.where(start_head, a, b),
                            eq_after, s.eq),
            # carry sub-tick execution debt so tick quantization does not
            # waste edge throughput (finish mid-tick → next task starts
            # from the leftover, like the continuous-time oracle)
            busy_rem=jnp.where(start, s.busy_rem + act, s.busy_rem),
            n_success=s.n_success.at[run_model].add(
                success.astype(jnp.int32)),
            n_edge_exec=s.n_edge_exec.at[run_model].add(
                start.astype(jnp.int32)),
            n_miss=s.n_miss.at[run_model].add(
                (start & ~success).astype(jnp.int32)),
            qos_utility=s.qos_utility + util)
        if pol.gems:
            m_ids = jnp.arange(prof.t_edge.shape[0], dtype=jnp.int32)
            run_onehot = (m_ids == run_model) & start
            s = _gems_bulk(s, prof, now, run_onehot & success, run_onehot,
                           m_ids)
        return s

    st = jax.lax.fori_loop(0, SUBSTEPS, body, st)
    # at most one tick of banked debt; idle edges do not accumulate credit
    return st._replace(busy_rem=jnp.maximum(st.busy_rem - dt, -dt))


def make_step(prof: Profiles, pol: FleetPolicy, dt: float,
              edge_frac: float, cloud_frac: float):
    """Build the single-edge tick function (to be vmapped over the fleet)."""
    min_edge_t = float(np.min(np.asarray(prof.t_edge)))
    m = prof.t_edge.shape[0]

    def step(st: EdgeState, inputs) -> tuple[EdgeState, None]:
        # arrive: bool[M]; order: i32[M]; theta/bw/load_mult per edge scalars
        now, theta, bw, arrive, order, load_mult, cloud_up = inputs
        # signed cellular transfer penalty (network.py convention); exactly
        # 0.0 at the nominal benchmark bandwidth
        bw_pen = network.bandwidth_penalty_ms(bw)
        st = _resolve_cloud(st, prof, now, theta, bw_pen, cloud_frac, pol,
                            cloud_up)
        # §3.3: tasks of a segment are inserted in randomized order
        def route_one(i, s):
            mdl = order[i]
            return _route_arrival(s, prof, now, mdl, pol, arrive[mdl],
                                  load_mult)
        st = jax.lax.fori_loop(0, m, route_one, st)
        st = _edge_execute(st, prof, now, dt, edge_frac, pol, min_edge_t)
        if pol.gems:
            st = _gems_act(st, prof, now, theta, bw_pen, cloud_frac, pol)
        return st, None

    return step


# ---------------------------------------------------------------------------
# cross-edge peer offload (fleet-level exchange between ticks)
# ---------------------------------------------------------------------------

def peer_offload(fs: EdgeState, now, slack_ms,
                 max_transfers: int) -> EdgeState:
    """Move doomed tasks from overloaded edges to the least-loaded peer.

    Operates on the *stacked* fleet state (leading edge axis).  Each of
    the ``max_transfers`` rounds picks the worst-min-slack edge *among
    those with an actually exportable task* (so an unexportable straggler
    cannot starve other overloaded edges), selects its worst-slack task
    that is still feasible behind the least-loaded other edge's queue,
    and re-homes it — the paper's §5.3 work-stealing idea lifted from
    edge↔cloud to edge↔edge.  Queue ``t_edge`` entries carry the source
    edge's speed factor; destination feasibility reuses them, which is
    conservative when the destination is faster.  Under a sharded fleet
    axis the gathers/scatters lower to cross-device collectives.
    """
    n_edges = fs.busy_rem.shape[0]
    if n_edges < 2:
        return fs

    def one_transfer(_, fs: EdgeState) -> EdgeState:
        busy = jnp.maximum(fs.busy_rem, 0.0)
        slacks = jax.vmap(js.queue_slacks, in_axes=(0, None, 0))(
            fs.eq, now, busy)                              # [E, Q]
        min_slack = slacks.min(-1)                         # [E]
        load = jax.vmap(js.queue_load)(fs.eq, fs.busy_rem)  # [E]

        # each edge's best available destination load (least-loaded other
        # edge): the global minimum, or the runner-up for that edge itself
        lead = jnp.argmin(load)
        runner_up = jnp.where(jnp.arange(n_edges) == lead, js.POS,
                              load).min()
        dst_load = jnp.where(jnp.arange(n_edges) == lead, runner_up,
                             load.min())                   # [E]
        exportable = (fs.eq.valid & (slacks < slack_ms)
                      & (now + dst_load[:, None] + fs.eq.t_edge
                         <= fs.eq.deadline)).any(-1)       # [E]
        over = (min_slack < slack_ms) & exportable
        src = jnp.argmin(jnp.where(over, min_slack, js.POS))
        dst = jnp.argmin(jnp.where(jnp.arange(n_edges) == src, js.POS, load))

        src_eq = jax.tree.map(lambda a: a[src], fs.eq)
        vidx = js.export_select(src_eq, now, busy[src], load[dst], slack_ms)
        ok = over.any() & (vidx >= 0)
        vi = jnp.maximum(vidx, 0)

        free = ~fs.eq.valid[dst]
        ok = ok & free.any()
        slot = jnp.argmax(free)
        eq = fs.eq
        moved = js.EdgeQueue(
            valid=eq.valid.at[src, vi].set(False).at[dst, slot].set(True),
            key=eq.key.at[dst, slot].set(src_eq.key[vi]),
            seq=eq.seq.at[dst, slot].set(fs.seq[dst]),
            t_edge=eq.t_edge.at[dst, slot].set(src_eq.t_edge[vi]),
            deadline=eq.deadline.at[dst, slot].set(src_eq.deadline[vi]),
            model=eq.model.at[dst, slot].set(src_eq.model[vi]))
        new_eq = jax.tree.map(lambda a, b: jnp.where(ok, a, b), moved, eq)
        oki = ok.astype(jnp.int32)
        return fs._replace(
            eq=new_eq,
            seq=fs.seq.at[dst].add(oki),
            n_peer_out=fs.n_peer_out.at[src].add(oki),
            n_peer_in=fs.n_peer_in.at[dst].add(oki))

    return jax.lax.fori_loop(0, max_transfers, one_transfer, fs)


def default_signals(n_models: int, *, n_edges: int, drones_per_edge: int = 3,
                    duration_ms: float = 300_000.0, dt: float = 25.0,
                    theta_fn=None, bw_fn=None, seed: int = 0) -> FleetSignals:
    """The paper's steady workload as dense tick signals (§8.1/§8.6).

    ``theta_fn`` / ``bw_fn`` shape the WAN latency and cellular bandwidth
    (defaults: no added latency, nominal bandwidth → zero transfer
    penalty).
    """
    m = n_models
    n_ticks = int(duration_ms / dt)
    rng = np.random.default_rng(seed)

    # one segment per drone per second → per-tick arrival counts; we spread
    # each drone's per-segment task burst across model slots determin.
    times = np.arange(n_ticks, dtype=np.float32) * dt
    arrive = np.zeros((n_ticks, n_edges, m), dtype=bool)
    for e in range(n_edges):
        for d in range(drones_per_edge):
            phase = rng.uniform(0, 1000.0)
            seg_t = np.arange(phase, duration_ms, 1000.0)
            ticks = np.minimum((seg_t / dt).astype(int), n_ticks - 1)
            arrive[ticks, e, :] = True
    theta_t = network.sample_trace(theta_fn, times) if theta_fn \
        else np.zeros(n_ticks, np.float32)
    theta = np.broadcast_to(theta_t[:, None], (n_ticks, n_edges))
    bw_t = network.sample_trace(bw_fn, times) if bw_fn \
        else np.full(n_ticks, network.NOMINAL_BW_MBPS, np.float32)
    bw = np.broadcast_to(bw_t[:, None], (n_ticks, n_edges))
    order = np.stack([rng.permuted(np.tile(np.arange(m), (n_edges, 1)),
                                   axis=1) for _ in range(n_ticks)]
                     ).astype(np.int32)
    return FleetSignals(
        times=jnp.asarray(times), theta=jnp.asarray(theta),
        bw=jnp.asarray(bw), arrive=jnp.asarray(arrive),
        order=jnp.asarray(order),
        load_mult=jnp.ones((n_ticks, n_edges), jnp.float32),
        cloud_up=jnp.ones(n_ticks, bool))


def _resolve_policy(policy) -> FleetPolicy:
    return policy if isinstance(policy, FleetPolicy) \
        else FleetPolicy.from_name(policy)


def _shard_leading(tree, mesh: jax.sharding.Mesh):
    """Shard every leaf's leading axis over the mesh's first axis name."""
    axis = mesh.axis_names[0]
    return jax.tree.map(
        lambda a: jax.device_put(a, jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(
                *([axis] + [None] * (a.ndim - 1))))), tree)


def _fleet_setup(models, policy, dt, edge_frac, cloud_frac, n_edges,
                 cloud_slots):
    """Shared run_fleet / run_fleet_batch setup: program + initial state."""
    pol = _resolve_policy(policy)
    prof = Profiles.build(models)
    run = _fleet_program(prof, pol, dt, edge_frac, cloud_frac, n_edges)
    state = jax.vmap(
        lambda _: init_state(prof, pol.adapt_window, cloud_slots))(
        jnp.arange(n_edges))
    return run, state


def _fleet_program(prof: Profiles, pol: FleetPolicy, dt: float,
                   edge_frac: float, cloud_frac: float, n_edges: int):
    """Build ``run(state, xs) -> final`` — the whole mission as one scan."""
    step = make_step(prof, pol, dt, edge_frac, cloud_frac)
    vstep = jax.vmap(step, in_axes=(0, (None, 0, 0, 0, 0, 0, None)))
    cooperate = pol.cooperation and n_edges > 1

    def scan_body(state, xs):
        now, th, bw, arr, ordr, lm, cup = xs
        state, _ = vstep(state, (now, th, bw, arr, ordr, lm, cup))
        if cooperate:
            state = peer_offload(state, now + dt, pol.coop_slack_ms,
                                 pol.coop_max_transfers)
        return state, None

    def run(state, xs):
        final, _ = jax.lax.scan(scan_body, state, xs)
        return final

    return run


def run_fleet(models: list[ModelProfile], policy, signals: FleetSignals, *,
              dt: float = 25.0, edge_frac: float = 0.62,
              cloud_frac: float = 0.80, cloud_slots: int = CLOUD_SLOTS,
              mesh: Optional[jax.sharding.Mesh] = None) -> EdgeState:
    """Run the fleet simulator over arbitrary scenario signals.

    ``policy`` is a :class:`FleetPolicy` or a name (``"DEMS"``,
    ``"GEMS-A-COOP"``, …).  ``cloud_slots`` is each edge's share of the
    bounded FaaS concurrency (the oracle's ``cloud_concurrency``); make it
    large to recover the elastic-cloud limit.  With ``mesh`` given, fleet
    state is sharded over its first axis (pjit-style data parallelism over
    edges); the peer offload exchange then runs as cross-device
    collectives.
    """
    run, state = _fleet_setup(models, policy, dt, edge_frac, cloud_frac,
                              signals.arrive.shape[1], cloud_slots)
    xs = tuple(signals)
    if mesh is not None:
        state = _shard_leading(state, mesh)
    return jax.jit(run)(state, xs)


def stack_signals(signals: list[FleetSignals]) -> FleetSignals:
    """Stack per-run signals over a new leading replica axis.

    All runs must share (n_ticks, n_edges, n_models) — i.e. seeds or event
    variants of one scenario shape, the unit :func:`run_fleet_batch`
    compiles once and sweeps in a single program.
    """
    return jax.tree.map(lambda *xs: jnp.stack(xs), *signals)


def run_fleet_batch(models: list[ModelProfile], policy,
                    signals: FleetSignals, *, dt: float = 25.0,
                    edge_frac: float = 0.62, cloud_frac: float = 0.80,
                    cloud_slots: int = CLOUD_SLOTS,
                    mesh: Optional[jax.sharding.Mesh] = None) -> EdgeState:
    """One-jit sweep: ``signals`` carry a leading replica axis ``[R, …]``
    (from :func:`stack_signals`), and the whole sweep — every replica's
    full mission scan — runs as a single ``vmap``-over-replicas compiled
    program instead of R sequential jits.

    Returns the stacked final :class:`EdgeState` with leading ``[R, E]``
    axes; slicing replica ``r`` reproduces ``run_fleet`` on that run's
    signals exactly.  With ``mesh`` given, replicas are sharded over its
    first axis, so independent seeds/scenario-variants fan out across
    devices.
    """
    run, state = _fleet_setup(models, policy, dt, edge_frac, cloud_frac,
                              signals.arrive.shape[2], cloud_slots)
    xs = tuple(signals)
    if mesh is not None:
        xs = _shard_leading(xs, mesh)
    return jax.jit(jax.vmap(run, in_axes=(None, 0)))(state, xs)


def simulate_fleet(models: list[ModelProfile], policy: str, *,
                   n_edges: int, drones_per_edge: int = 3,
                   duration_ms: float = 300_000.0, dt: float = 25.0,
                   edge_frac: float = 0.62, cloud_frac: float = 0.80,
                   cloud_slots: int = CLOUD_SLOTS,
                   theta_fn=None, bw_fn=None, seed: int = 0,
                   mesh: Optional[jax.sharding.Mesh] = None) -> EdgeState:
    """Simulate ``n_edges`` base stations under the paper's steady
    workload; returns stacked final states.  Scenario-driven runs (bursts,
    mobility, outages, …) go through :func:`run_fleet` with signals from
    :mod:`repro.scenarios.compile`."""
    signals = default_signals(len(models), n_edges=n_edges,
                              drones_per_edge=drones_per_edge,
                              duration_ms=duration_ms, dt=dt,
                              theta_fn=theta_fn, bw_fn=bw_fn, seed=seed)
    return run_fleet(models, policy, signals, dt=dt, edge_frac=edge_frac,
                     cloud_frac=cloud_frac, cloud_slots=cloud_slots,
                     mesh=mesh)
