"""Network and execution-latency models (paper §1.2 Figs 1–2, §8.5).

The paper benchmarks per-model latency distributions on a Jetson-class edge
(tight, Fig 1a) and AWS Lambda over WAN (long-tailed, Fig 1b), then *shapes*
the edge↔cloud link during experiments:

* latency: a "trapezium" waveform θ(t) ramping 0→400 ms over [60 s, 90 s),
  holding, and ramping down over [210 s, 240 s)  (§8.5, Fig 12a)
* bandwidth: SUMO+NS3 cellular traces from 7 mobile devices (Fig 2c) — we
  synthesize statistically similar traces with a bounded random walk.

All times ms, bandwidth Mbps, sizes kB.  Samplers draw from a
``numpy.random.Generator`` owned by the simulator so runs are reproducible.

Trace functions (``constant``, ``trapezium``, ``cellular_bandwidth_trace``)
are **array-native**: called with an ``np.ndarray`` of times they return an
array of the same shape, so scenario compilation evaluates a whole mission's
tick grid in one call instead of a Python loop per tick.  Scalar calls
still return plain floats.

Bandwidth-penalty convention (shared by the oracle's ``shaped_delta`` and
the fleet simulator's dense ``bw`` signal): the penalty is the **signed**
difference ``transfer_ms(SEGMENT_KB, bw(t)) − transfer_ms(SEGMENT_KB,
NOMINAL_BW_MBPS)``.  Bandwidth below nominal slows the transfer down;
bandwidth *above* nominal speeds it up, bounded below by
``−transfer_ms(SEGMENT_KB, NOMINAL_BW_MBPS)`` (a transfer can at best
become free — ``transfer_ms`` is never negative, so the floor is
automatic).  At ``bw ≡ NOMINAL_BW_MBPS`` the penalty is exactly zero.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

SEGMENT_KB = 38.0          # 1 s video segment size (§8.1)
NOMINAL_BW_MBPS = 20.0     # bandwidth assumed by the t̂ benchmarks


def transfer_ms(size_kb: float, bw_mbps: float) -> float:
    """Transfer time of ``size_kb`` at ``bw_mbps`` (8 kb per kB)."""
    return size_kb * 8.0 / max(bw_mbps, 1e-3)


def bandwidth_penalty_ms(bw_mbps, segment_kb: float = SEGMENT_KB):
    """Signed shaping delta vs the nominal benchmark bandwidth.

    Works on scalars and arrays (``np`` or ``jnp``); the two ``transfer``
    terms use the identical expression so the penalty is exactly ``0.0``
    at ``bw_mbps == NOMINAL_BW_MBPS``.
    """
    clipped = np.maximum(bw_mbps, 1e-3) if isinstance(
        bw_mbps, (int, float, np.ndarray)) else bw_mbps.clip(1e-3)
    return (segment_kb * 8.0 / clipped
            - segment_kb * 8.0 / NOMINAL_BW_MBPS)


def sample_trace(fn: Callable, times: np.ndarray) -> np.ndarray:
    """Evaluate a trace over a time grid in one call.

    Array-native trace functions (everything in this module) evaluate
    vectorized; foreign scalar-only callables fall back to a Python loop.
    """
    times = np.asarray(times)
    try:
        out = np.asarray(fn(times), dtype=np.float32)
        if out.shape == times.shape:
            return out
    except (TypeError, ValueError):
        pass
    return np.asarray([fn(float(t)) for t in times], dtype=np.float32)


# ---------------------------------------------------------------------------
# Latency / bandwidth shaping traces
# ---------------------------------------------------------------------------

def _scalarize(out: np.ndarray, t) -> np.ndarray | float:
    return out if np.ndim(t) else float(out)


def constant(value: float) -> Callable[[float], float]:
    def trace(t):
        return _scalarize(np.full(np.shape(t), value, dtype=float), t)
    return trace


def trapezium(low: float = 0.0, high: float = 400.0,
              ramp_up: tuple[float, float] = (60_000.0, 90_000.0),
              ramp_down: tuple[float, float] = (210_000.0, 240_000.0),
              ) -> Callable[[float], float]:
    """§8.5 trapezium waveform for added one-way latency θ(t)."""
    u0, u1 = ramp_up
    d0, d1 = ramp_down
    # degenerate (step) ramps select an empty branch below, but the ramp
    # expressions are evaluated unconditionally — keep their denominators
    # nonzero so a step ramp doesn't emit divide-by-zero warnings
    du = max(u1 - u0, 1e-9)
    dd = max(d1 - d0, 1e-9)

    def theta(t):
        ta = np.asarray(t, dtype=float)
        up = low + (high - low) * (ta - u0) / du
        down = high - (high - low) * (ta - d0) / dd
        out = np.where((ta < u0) | (ta >= d1), low,
                       np.where(ta < u1, up,
                                np.where(ta < d0, high, down)))
        return _scalarize(out, t)

    return theta


def cellular_bandwidth_trace(seed: int = 7, duration_ms: float = 600_000.0,
                             step_ms: float = 1_000.0, lo: float = 0.25,
                             hi: float = 40.0, start: float = 18.0,
                             ) -> Callable[[float], float]:
    """Synthetic mobile 4G bandwidth trace (Fig 2c analogue).

    Bounded multiplicative random walk with occasional deep fades, matching
    the high divergence across mobile devices the paper reports.  The walk
    is seeded at its anchor: ``bw(0) == clip(start)`` exactly, and steps
    perturb from there.  Queries beyond ``duration_ms`` wrap around
    (periodic extension) — explicit and documented, instead of silently
    pinning to the last sample.
    """
    rng = np.random.default_rng(seed)
    n = int(duration_ms / step_ms) + 1
    vals = np.empty(n)
    vals[0] = min(max(start, lo), hi)
    v = vals[0]
    for i in range(1, n):
        v *= math.exp(rng.normal(0.0, 0.25))
        if rng.random() < 0.04:       # deep fade (underpass / handover)
            v *= 0.08
        v = min(max(v, lo), hi)
        vals[i] = v

    def bw(t):
        idx = (np.asarray(t, dtype=float) / step_ms).astype(int) % n
        return _scalarize(vals[idx], t)

    return bw


# ---------------------------------------------------------------------------
# Execution-duration samplers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EdgeLatencyModel:
    """Actual edge duration t̄_i^j around the 99th-pct estimate t_i (Fig 1a).

    The estimate is a p99, so actual durations are usually *below* it —
    this is precisely the slack that work stealing (§5.3) exploits.
    """

    mean_frac: float = 0.62
    sd_frac: float = 0.10
    lo_frac: float = 0.42
    hi_frac: float = 1.10   # rare overruns beyond the p99 estimate
    spike_p: float = 0.0    # transient stalls (GC pause, thermal throttle)
    spike_mult: float = 1.4

    def sample(self, rng: np.random.Generator, t_edge: float,
               now: float = 0.0, model: str | None = None) -> float:
        # ``now``/``model`` let table-backed subclasses share the fleet's
        # per-(tick, model) draws; the distributional model ignores them
        f = rng.normal(self.mean_frac, self.sd_frac)
        f = float(np.clip(f, self.lo_frac, self.hi_frac))
        if self.spike_p and rng.random() < self.spike_p:
            f *= self.spike_mult
        return t_edge * f


@dataclasses.dataclass
class CloudLatencyModel:
    """Actual cloud duration: FaaS execution + WAN effects (Fig 1b, 2).

    ``t̂`` is the benchmarked p95 end-to-end estimate.  We decompose the
    sample into a lognormal body calibrated so ~5 % of unshaped samples
    exceed t̂, plus shaped deltas: added latency θ(t) and the **signed**
    bandwidth penalty relative to the nominal benchmark bandwidth (see
    module docstring; the fleet simulator's ``bw`` signal applies the
    identical convention).  Cold starts appear as a small probability of
    a large multiplier (§4, [47]).
    """

    median_frac: float = 0.70
    sigma: float = 0.18           # p95 of LogNormal(ln .7, .18) ≈ 0.94·t̂
    cold_start_p: float = 0.01
    cold_start_ms: float = 900.0
    latency_at: Callable[[float], float] = dataclasses.field(
        default_factory=lambda: constant(0.0))
    bandwidth_at: Callable[[float], float] = dataclasses.field(
        default_factory=lambda: constant(NOMINAL_BW_MBPS))
    segment_kb: float = SEGMENT_KB

    def shaped_delta(self, now: float) -> float:
        """Deterministic extra latency from shaping at time ``now``.

        ``θ(now)`` plus the signed bandwidth penalty: below-nominal
        bandwidth adds transfer time, above-nominal subtracts it (floored
        at ``−transfer_ms(segment_kb, NOMINAL_BW_MBPS)`` by construction).
        """
        return self.latency_at(now) + bandwidth_penalty_ms(
            self.bandwidth_at(now), self.segment_kb)

    def sample(self, rng: np.random.Generator, t_cloud: float,
               now: float, model: str | None = None) -> float:
        body = t_cloud * float(rng.lognormal(math.log(self.median_frac),
                                             self.sigma))
        if rng.random() < self.cold_start_p:
            body += self.cold_start_ms
        return body + self.shaped_delta(now)


# ---------------------------------------------------------------------------
# Table-backed samplers: the oracle drawing the *fleet's* samples
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TableEdgeLatencyModel(EdgeLatencyModel):
    """Edge durations from a per-(tick, model) multiplier table.

    ``table`` is the ``float32 [T, M]`` edge lane of
    :func:`repro.scenarios.compile.compile_exec_jitter` — the *same*
    array the fleet simulator consumes as ``FleetSignals.exec_jit[...,
    0]`` — so a task executing at time ``now`` draws the identical
    multiplier in both backends and fleet-vs-oracle agreement holds on
    stochastic scenarios.  ``base_frac`` is the fleet's deterministic
    ``edge_frac`` (0.62): the sampled duration is
    ``t_edge · base_frac · table[now // dt, model]``.
    """

    table: np.ndarray | None = None
    names: tuple[str, ...] = ()
    dt: float = 25.0
    base_frac: float = 0.62

    def __post_init__(self):
        self._idx = {n: i for i, n in enumerate(self.names)}

    def sample(self, rng: np.random.Generator, t_edge: float,
               now: float = 0.0, model: str | None = None) -> float:
        tick = min(int(now / self.dt), self.table.shape[0] - 1)
        jit = float(self.table[tick, self._idx[model]]) \
            if model is not None else 1.0
        return t_edge * self.base_frac * jit


@dataclasses.dataclass
class TableCloudLatencyModel(CloudLatencyModel):
    """Cloud durations from a per-(tick, model) multiplier table.

    The cloud lane of :func:`repro.scenarios.compile.compile_exec_jitter`
    (``FleetSignals.exec_jit[..., 1]``); the multiplier scales the
    compute body only — θ(t)/bandwidth shaping stays the additive
    ``shaped_delta``, exactly like the fleet's act formula.  ``base_frac``
    is the fleet's deterministic ``cloud_frac`` (0.80); the lognormal /
    cold-start machinery of the parent is bypassed entirely, so given the
    table the sample is deterministic.
    """

    table: np.ndarray | None = None
    names: tuple[str, ...] = ()
    dt: float = 25.0
    base_frac: float = 0.80

    def __post_init__(self):
        self._idx = {n: i for i, n in enumerate(self.names)}

    def sample(self, rng: np.random.Generator, t_cloud: float,
               now: float, model: str | None = None) -> float:
        tick = min(int(now / self.dt), self.table.shape[0] - 1)
        jit = float(self.table[tick, self._idx[model]]) \
            if model is not None else 1.0
        return t_cloud * self.base_frac * jit + self.shaped_delta(now)
