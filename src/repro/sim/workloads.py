"""Workload generators (paper §8.1, §8.3, §8.7).

Each drone streams video; the splitter cuts 1 s segments, and the task
creator emits one task per registered DNN model per segment, inserted in a
*randomized order* (§3.3) to avoid favoring any model.

Standard QoS workloads: {2,3,4} drones × {Passive, Active} over 300 s →
2400–7200 tasks per base station (matching §8.3's counts).  GEMS QoE
workloads WL1/WL2 use the Table-2 profiles with α ∈ {0.9, 1.0}.
"""
from __future__ import annotations

import numpy as np

from repro.core.task import ACTIVE, PASSIVE, TABLE1, ModelProfile, table2
from repro.sim.engine import Arrival

DEFAULT_DURATION_MS = 300_000.0
SEGMENT_MS = 1_000.0


def task_stream(models: list[ModelProfile], n_drones: int,
                duration_ms: float = DEFAULT_DURATION_MS,
                segment_ms: float = SEGMENT_MS,
                seed: int = 0) -> list[Arrival]:
    """One task per (drone, segment, model), model order shuffled/segment."""
    rng = np.random.default_rng(seed)
    arrivals: list[Arrival] = []
    n_segments = int(duration_ms / segment_ms)
    for d in range(n_drones):
        # drones are not frame-synchronized: random phase within a segment
        phase = float(rng.uniform(0, segment_ms))
        for s in range(n_segments):
            t = s * segment_ms + phase
            if t >= duration_ms:
                continue
            order = rng.permutation(len(models))
            for k in order:
                arrivals.append(Arrival(time=t, model=models[int(k)], drone=d))
    return arrivals


def standard(workload: str, duration_ms: float = DEFAULT_DURATION_MS,
             seed: int = 0) -> list[Arrival]:
    """Paper workloads ``{2,3,4}D-{P,A}``, e.g. ``"4D-A"`` (§8.3)."""
    drones = int(workload[0])
    kind = workload.split("-")[1]
    names = PASSIVE if kind == "P" else ACTIVE
    models = [TABLE1[n] for n in names]
    return task_stream(models, drones, duration_ms, seed=seed)


STANDARD_WORKLOADS = ("2D-P", "2D-A", "3D-P", "3D-A", "4D-P", "4D-A")


def gems_workload(name: str, alpha: float,
                  n_drones: int = 3,
                  duration_ms: float = DEFAULT_DURATION_MS,
                  seed: int = 0) -> list[Arrival]:
    """GEMS QoE workloads WL1/WL2 (§8.7, Table 2), α ∈ {0.9, 1.0}."""
    models = table2(name, alpha)
    return task_stream(models, n_drones, duration_ms, seed=seed)
