"""Observability: flight recorder, QoS/QoE tail metrics, profiling hooks.

Three layers (see ``docs/OBSERVABILITY.md``):

* :mod:`repro.obs.trace` — the in-program decision-trace schema
  (:class:`TraceSpec`, :class:`TickCounters`) tapped out of the compiled
  fleet tick scan by :mod:`repro.sim.fleet_jax`;
* :mod:`repro.obs.metrics` — host-side aggregation: QoS/QoE time
  series, per-task-type success frequencies (the paper's QoE metric),
  p50/p95/p99 deadline-slack and completion-latency percentiles, the
  per-tick conservation ledger, and JSON/CSV/Perfetto export;
* :mod:`repro.obs.prof` — ``jax.profiler`` trace capture plus
  compile/retrace accounting for the policy-generic tick program.
"""
from repro.obs.trace import (EVENT_FIELDS, TickCounters, TraceSpec,
                             hist_counts, resolve_spec, zero_counters)

__all__ = [
    "EVENT_FIELDS", "TickCounters", "TraceSpec", "hist_counts",
    "resolve_spec", "zero_counters",
]
