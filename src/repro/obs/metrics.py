"""Host-side aggregation of flight-recorder traces.

Everything here consumes the ``TickCounters`` stream emitted by the
compiled fleet tick (:mod:`repro.sim.fleet_jax` with
``trace=TraceSpec(counters=True)``) as plain NumPy in the ``[T, E, …]``
layout — the shape :func:`run_fleet` returns and the shape
:func:`run_registry_sweep` re-stacks each row's ``"trace"`` into.  For
``run_fleet_batch``'s ``[R, T, E, …]`` streams, pick a replica first
with :func:`select_replica`.

The three product surfaces:

* :func:`time_series` — fleet-summed per-tick QoS/QoE and decision
  series (the figures' raw material);
* :func:`tail_metrics` — the paper's distributional claims as numbers:
  per-task-type success frequencies (QoE), deadline-hit rate, the
  windowed p95/p99 deadline-hit tail (:func:`deadline_hit_tail`), and
  p50/p95/p99 deadline-slack / completion-latency percentiles read out
  of the in-program histograms (:func:`hist_percentiles`);
* :func:`conservation_ledger` / :func:`check_conservation` — the
  per-tick accounting identity ``arrived = settled + in-flight``
  (fleet-summed: peer offload moves tasks *between* edges).

Exports: :func:`to_json`, :func:`to_csv` (one row per tick) and
:func:`to_perfetto` (Chrome/Perfetto trace-event counter stream).
"""
from __future__ import annotations

import csv
import io
import json
from typing import Mapping, Sequence

import numpy as np

from repro.obs.trace import TickCounters, TraceSpec

PERCENTILES = (50.0, 95.0, 99.0)


def _np(counters: TickCounters) -> TickCounters:
    return TickCounters(*(np.asarray(x) for x in counters))


def select_replica(counters: TickCounters, r: int) -> TickCounters:
    """Slice one replica out of a batch-path ``[R, T, E, …]`` stream."""
    return TickCounters(*(np.asarray(x)[r] for x in counters))


def bin_edges(spec: TraceSpec) -> np.ndarray:
    """The ``hist_bins + 1`` bucket boundaries in ms (last = +inf)."""
    w = spec.hist_max_ms / spec.hist_bins
    edges = np.arange(spec.hist_bins + 1, dtype=np.float64) * w
    edges[-1] = np.inf
    return edges


def hist_percentiles(hist: np.ndarray, spec: TraceSpec,
                     qs: Sequence[float] = PERCENTILES) -> dict[str, float]:
    """Percentiles from a fixed-bin histogram, interpolated within bins.

    ``hist`` is any ``[…, B]`` stack of per-tick histograms; all leading
    axes are summed first.  Counts are exact; values are linear
    interpolations inside the hit bucket, so the error is bounded by one
    bin width (the last bucket also absorbs overflow, so values cap at
    ``hist_max_ms``).  Empty histograms give ``nan``.
    """
    h = np.asarray(hist, dtype=np.float64)
    h = h.reshape(-1, h.shape[-1]).sum(0)
    total = h.sum()
    out: dict[str, float] = {}
    if total == 0:
        return {f"p{q:g}": float("nan") for q in qs}
    cum = np.cumsum(h)
    w = spec.hist_max_ms / spec.hist_bins
    for q in qs:
        target = q / 100.0 * total
        k = int(np.searchsorted(cum, target, side="left"))
        k = min(k, len(h) - 1)
        below = cum[k] - h[k]
        frac = (target - below) / h[k] if h[k] else 0.0
        out[f"p{q:g}"] = (k + frac) * w
    return out


def time_series(counters: TickCounters) -> dict[str, np.ndarray]:
    """Fleet-summed per-tick series (length T) from a ``[T, E, …]`` stream.

    Per-model leaves and histograms are summed over their trailing axis
    too, so every value is a scalar per tick; ``valid`` becomes the
    count of live edges that tick.
    """
    c = _np(counters)
    out: dict[str, np.ndarray] = {}
    for name, leaf in c._asdict().items():
        a = np.asarray(leaf)
        reduced = a.reshape(a.shape[0], -1).sum(1)
        out[name] = reduced.astype(np.int64) if a.dtype != np.float32 \
            else reduced.astype(np.float64)
    out["settled"] = out["hit"] + out["miss"] + out["drop"]
    out["in_flight"] = out["eq_depth"] + out["cq_depth"]
    return out


def conservation_ledger(counters: TickCounters) -> dict[str, np.ndarray]:
    """Cumulative ledger: ``arrived = settled + in_flight`` per tick.

    Fleet-summed — peer offload moves a task between edges without
    settling it, so the identity holds fleet-wide (and per edge only in
    non-cooperative runs).  ``residual`` should be identically zero.
    """
    ts = time_series(counters)
    arrived = np.cumsum(ts["arrivals"])
    settled = np.cumsum(ts["settled"])
    in_flight = ts["in_flight"]
    return dict(arrived=arrived, settled=settled, in_flight=in_flight,
                residual=arrived - settled - in_flight)


def check_conservation(counters: TickCounters) -> None:
    """Raise ``AssertionError`` with the first offending tick on leak."""
    resid = conservation_ledger(counters)["residual"]
    bad = np.nonzero(resid)[0]
    if bad.size:
        t = int(bad[0])
        raise AssertionError(
            f"task conservation violated from tick {t}: residual "
            f"{int(resid[t])} (arrived != settled + in-flight)")


def deadline_hit_tail(counters: TickCounters, *,
                      window_ms: float = 1_000.0,
                      dt_ms: float = 25.0) -> dict[str, float]:
    """Tail-QoS scoreboard: windowed deadline-hit rate percentiles.

    The per-tick fleet-summed hit/miss/drop series is aggregated into
    ``window_ms`` buckets; each bucket's hit rate ``hit / settled`` is
    one observation, and the *lower* tail of that distribution is the
    service-level number a fleet operator cares about — "in the worst
    1 % of seconds, what fraction of frames still met their deadline?".
    Reported as ``mean`` plus ``p95``/``p99`` (the 5th/1st percentile of
    per-window hit rates, i.e. the rate the fleet beats 95 %/99 % of the
    time).  Windows where nothing settled are skipped; an all-idle run
    gives ``nan``.
    """
    ts = time_series(counters)
    per = max(int(round(window_ms / dt_ms)), 1)
    n = len(ts["hit"])
    rates = []
    for s in range(0, n, per):
        hit = float(ts["hit"][s:s + per].sum())
        settled = float(ts["settled"][s:s + per].sum())
        if settled > 0:
            rates.append(hit / settled)
    if not rates:
        nan = float("nan")
        return dict(mean=nan, p95=nan, p99=nan, windows=0)
    r = np.asarray(rates, dtype=np.float64)
    return dict(mean=float(r.mean()),
                p95=float(np.percentile(r, 5.0)),
                p99=float(np.percentile(r, 1.0)),
                windows=int(r.size))


def qoe_frequencies(counters: TickCounters,
                    model_names: Sequence[str] | None = None
                    ) -> dict[str, float]:
    """Per-task-type success frequency hit/(hit+miss+drop) — the QoE metric.

    Padded model lanes (batch sweeps pad M to the registry maximum)
    never settle a task and are omitted.
    """
    c = _np(counters)
    hit = c.hit.reshape(-1, c.hit.shape[-1]).sum(0)
    settled = hit + c.miss.reshape(-1, c.miss.shape[-1]).sum(0) \
        + c.drop.reshape(-1, c.drop.shape[-1]).sum(0)
    out = {}
    for m in range(hit.shape[0]):
        if settled[m] == 0:
            continue
        name = model_names[m] if model_names and m < len(model_names) \
            else f"model{m}"
        out[name] = float(hit[m] / settled[m])
    return out


def tail_metrics(counters: TickCounters, spec: TraceSpec,
                 model_names: Sequence[str] | None = None) -> dict:
    """The distributional scoreboard for one traced run.

    Returns deadline-hit/miss/drop totals and rate, the windowed
    tail-QoS scoreboard (:func:`deadline_hit_tail`), per-task-type QoE
    success frequencies, and p50/p95/p99 deadline-slack and
    completion-latency percentiles (successful tasks; ms, bin-width
    resolution).
    """
    c = _np(counters)
    hit = int(c.hit.sum())
    miss = int(c.miss.sum())
    drop = int(c.drop.sum())
    settled = max(hit + miss + drop, 1)
    return dict(
        hit=hit, miss=miss, drop=drop,
        hit_rate=hit / settled,
        deadline_hit=deadline_hit_tail(counters),
        qoe_frequency=qoe_frequencies(counters, model_names),
        slack_ms=hist_percentiles(c.slack_hist, spec),
        latency_ms=hist_percentiles(c.latency_hist, spec),
        drops_by_cause=dict(
            infeasible=int(c.drop_infeasible.sum()),
            unstolen=int(c.drop_unstolen.sum()),
            queue_full=int(c.drop_qfull.sum()),
            crash=int(c.drop_crash.sum()),
            timeout=int(c.drop_timeout.sum())),
        qos_utility=float(c.qos.sum()),
        qoe_utility=float(c.qoe.sum()))


def to_json(counters: TickCounters, spec: TraceSpec,
            model_names: Sequence[str] | None = None, *,
            indent: int | None = None) -> str:
    """Full dump: tail metrics + ledger + per-tick series as JSON."""
    ts = {k: v.tolist() for k, v in time_series(counters).items()}
    ledger = {k: v.tolist()
              for k, v in conservation_ledger(counters).items()}
    doc = dict(spec=dict(hist_bins=spec.hist_bins,
                         hist_max_ms=spec.hist_max_ms),
               tail=tail_metrics(counters, spec, model_names),
               ledger=ledger, series=ts)
    return json.dumps(doc, indent=indent)


def to_csv(counters: TickCounters) -> str:
    """One row per tick of the fleet-summed series (spreadsheet food)."""
    ts = time_series(counters)
    cols = list(ts)
    buf = io.StringIO()
    w = csv.writer(buf)
    w.writerow(["tick", *cols])
    for t in range(len(ts["arrivals"])):
        w.writerow([t, *(ts[c][t] for c in cols)])
    return buf.getvalue()


def to_perfetto(counters: TickCounters, *, dt_ms: float = 25.0,
                stride: int = 1,
                process_name: str = "fleet") -> str:
    """Chrome/Perfetto trace-event JSON: one counter track per series.

    Every fleet-summed series becomes a phase-``"C"`` counter event at
    its tick's timestamp (µs).  ``stride`` downsamples long runs; load
    the result in ``ui.perfetto.dev`` or ``chrome://tracing``.
    """
    ts = time_series(counters)
    events: list[dict] = [dict(
        name="process_name", ph="M", pid=1,
        args=dict(name=process_name))]
    tracks = {
        "queues": ("eq_depth", "cq_depth", "slots_busy"),
        "outcomes": ("hit", "miss", "drop"),
        "routing": ("arrivals", "admit_edge", "admit_cloud",
                    "cloud_dispatch", "pool_blocked"),
        "rebalance": ("migrated", "gems_moved", "stolen",
                      "peer_out", "peer_in"),
        "utility": ("qos", "qoe"),
    }
    n = len(ts["arrivals"])
    for t in range(0, n, max(stride, 1)):
        us = t * dt_ms * 1_000.0
        for track, fields in tracks.items():
            events.append(dict(
                name=track, ph="C", pid=1, ts=us,
                args={f: float(ts[f][t]) for f in fields}))
    return json.dumps(dict(traceEvents=events,
                           displayTimeUnit="ms"))


def summarize_rows(rows: Sequence[Mapping], spec: TraceSpec) -> list[dict]:
    """Tail metrics for each traced :func:`run_registry_sweep` row."""
    out = []
    for row in rows:
        tr = row.get("trace")
        if tr is None or tr.counters is None:
            continue
        out.append(dict(scenario=row["scenario"], policy=row["policy"],
                        seed=row["seed"],
                        **tail_metrics(tr.counters, spec)))
    return out
