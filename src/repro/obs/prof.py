"""Profiling hooks: ``jax.profiler`` capture and compile accounting.

Two concerns, both about the *compiled program*, not the scheduler:

* :func:`profile_trace` — a context manager around
  ``jax.profiler.trace`` that captures a TensorBoard/Perfetto-readable
  device trace into a log directory (no-op with a warning if the
  profiler backend is unavailable in this build).
* :class:`CompileCounter` / :func:`fleet_compile_stats` — retrace
  accounting.  The fleet tick is policy-*generic*: every policy is
  runtime ``PolicyParams`` data, so one ``(dt, fractions, trace spec,
  layout)`` cell of :func:`repro.sim.fleet_jax._fleet_program` must
  trace **once** no matter how many policies run through it.  A leak of
  policy data into a static argument shows up here as extra traces —
  ``tests/conftest.py``'s ``compile_guard`` fixture turns that into a
  test failure.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings

import jax

# The monitoring event XLA fires once per backend compile.  Counting it
# sees through every cache layer (lru_cache, jit trace cache,
# persistent compilation cache misses).
BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


@contextlib.contextmanager
def profile_trace(logdir: str, *, create_perfetto_trace: bool = False):
    """Capture a ``jax.profiler`` device trace into ``logdir``.

    View with TensorBoard's profile plugin or (with
    ``create_perfetto_trace=True``) the generated ``.perfetto-trace``
    file in ``ui.perfetto.dev``.  Degrades to a no-op with a warning
    when the profiler backend refuses to start (some CPU-only or
    sandboxed builds).
    """
    try:
        jax.profiler.start_trace(
            logdir, create_perfetto_trace=create_perfetto_trace)
    except BaseException as e:  # backend may raise non-Exception errors
        warnings.warn(f"jax.profiler unavailable ({e!r}); "
                      "profile_trace is a no-op", RuntimeWarning)
        yield False
        return
    try:
        yield True
    finally:
        jax.profiler.stop_trace()


class CompileCounter:
    """Count XLA backend compiles (and their wall time) in a scope.

    >>> with CompileCounter() as cc:
    ...     run_fleet(...)
    >>> cc.count, cc.total_secs

    Uses :mod:`jax.monitoring` duration events, so it observes real
    backend compiles only — cache hits (jit or persistent) don't count.
    """

    def __init__(self) -> None:
        self.count = 0
        self.total_secs = 0.0

    def _listen(self, event: str, duration: float, **kw) -> None:
        if event == BACKEND_COMPILE_EVENT:
            self.count += 1
            self.total_secs += duration

    def __enter__(self) -> "CompileCounter":
        jax.monitoring.register_event_duration_secs_listener(self._listen)
        return self

    def __exit__(self, *exc) -> None:
        # public unregister didn't exist yet in this jax; fall back to
        # leaving the (cheap, inert) listener registered if the private
        # helper moves
        try:
            from jax._src.monitoring import \
                _unregister_event_duration_listener_by_callback
            _unregister_event_duration_listener_by_callback(self._listen)
        except (ImportError, ValueError):  # pragma: no cover
            pass


@dataclasses.dataclass
class FleetCompileStats:
    """Snapshot of the policy-generic tick program's trace caches."""

    programs: int        # distinct (dt, fracs, tspec, layout) programs
    traces: int          # total jit traces across all of them
    max_traces_per_program: int
    capacity: int = 0    # bounded program-cache size (LRU eviction past it)
    evictions: int = 0   # programs evicted since the last reset

    @property
    def policy_generic(self) -> bool:
        """True iff no program traced twice.

        Valid verdict only when every program saw a single input shape
        (e.g. after :func:`reset_fleet_programs`, one workload, many
        policies) — shape changes legitimately retrace.  For
        shape-varied sessions, compare :attr:`traces` deltas instead
        (the ``compile_guard`` fixture's approach).
        """
        return self.max_traces_per_program <= 1


def fleet_compile_stats() -> FleetCompileStats:
    """Read the live ``_fleet_program`` cache: programs × jit traces.

    Each cached program is a ``jax.jit`` wrapper; its ``_cache_size()``
    is how many distinct argument structures (shapes/dtypes) traced
    through it.  Growth *without* a new input shape means some runtime
    input (usually a policy field) leaked into the static/trace-level
    signature.
    """
    from repro.sim import fleet_jax

    sizes = []
    for prog in fleet_jax._PROGRAM_REGISTRY:
        try:
            sizes.append(prog._cache_size())
        except Exception:  # pragma: no cover - older jax
            sizes.append(1)
    return FleetCompileStats(
        programs=len(sizes), traces=sum(sizes),
        max_traces_per_program=max(sizes, default=0),
        capacity=fleet_jax.FLEET_PROGRAM_CACHE_CAPACITY,
        evictions=fleet_jax._PROGRAM_EVICTIONS)


def reset_fleet_programs() -> None:
    """Drop all cached tick programs (test isolation for retrace guards)."""
    from repro.sim import fleet_jax

    fleet_jax._fleet_program.cache_clear()
    fleet_jax._PROGRAM_REGISTRY.clear()
    fleet_jax._PROGRAM_EVICTIONS = 0
