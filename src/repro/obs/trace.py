"""Flight-recorder trace layer: what the compiled fleet tick records.

The fleet simulator's tick scan (:mod:`repro.sim.fleet_jax`) is one
compiled program; the only way to see *why* a policy wins — which ticks
dropped, stole, migrated, or missed deadlines — is to tap the scan's
carry and emit extra outputs.  This module defines that tap:

* :class:`TraceSpec` — a frozen, hashable request for which streams to
  record.  It is part of the compiled program's cache key, so the
  trace-off program is *literally the same executable* as before the
  flight recorder existed (zero cost, bit-identical results), and every
  trace computation is read-only on the scheduler state (trace-on runs
  produce bit-identical summaries; ``tests/test_obs.py`` pins both).
* :class:`TickCounters` — the dense per-tick decision counters, one
  value per (tick, edge) cell [fleet axis added by ``vmap``, tick axis
  by ``scan``, replica axis by the batch paths].  Event counters are
  zeroed on ``valid=False`` (padded) cells; *level* gauges (queue
  depths, slot occupancy) carry the reverted pre-tick state instead, so
  the conservation ledger ``arrived = settled + in-flight`` stays exact
  through a padded tail.
* histogram helpers — deadline slack and completion latency are
  recorded as fixed-bin histograms (``hist_bins`` buckets over
  ``[0, hist_max_ms)``, last bucket catches overflow), the dense-tensor
  answer to "per-task percentiles" that needs no per-task storage:
  p50/p95/p99 come out host-side with bin-width resolution
  (:func:`repro.obs.metrics.hist_percentiles`).

Nothing here imports the simulator — the dependency points the other
way (``fleet_jax`` imports the spec and counter schema), keeping the
recorder reusable by any scan-shaped program.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """What the fleet tick scan should record (hashable → cache key).

    ``t_hat``
        the per-tick adapted cloud-latency estimate ``adapt.current``
        (the legacy ``record_trace=True`` stream; shape ``[T, E, M]``
        from :func:`~repro.sim.fleet_jax.run_fleet`, ``[R, T, E, M]``
        from the batch paths).
    ``counters``
        the full :class:`TickCounters` decision stream.
    ``hist_bins`` / ``hist_max_ms``
        resolution of the slack/latency histograms: ``hist_bins``
        equal buckets over ``[0, hist_max_ms)`` ms, the last bucket
        absorbing anything larger.
    """

    t_hat: bool = False
    counters: bool = False
    hist_bins: int = 32
    hist_max_ms: float = 4_000.0

    @property
    def enabled(self) -> bool:
        return self.t_hat or self.counters

    @classmethod
    def off(cls) -> "TraceSpec":
        return cls()

    @classmethod
    def full(cls, **kw) -> "TraceSpec":
        return cls(t_hat=True, counters=True, **kw)


class TickCounters(NamedTuple):
    """Per-(tick, edge) decision counters emitted by the tick scan.

    Scalars are ``i32[]`` per edge before stacking; the batch paths
    deliver ``[R, T, E]`` (``[T, E]`` from :func:`run_fleet`), per-model
    leaves ``[…, M]`` and histograms ``[…, B]``.  Event counters count
    *this tick's* decisions; ``eq_depth``/``cq_depth``/``slots_busy``
    and ``valid`` are end-of-tick gauges.  See ``docs/OBSERVABILITY.md``
    for the full glossary.
    """

    # --- routing / admission events -----------------------------------
    arrivals: jax.Array        # tasks arriving at this edge
    admit_edge: jax.Array      # inserted into the edge queue
    admit_cloud: jax.Array     # pushed onto the cloud queue (incl. victims)
    migrated: jax.Array        # §5.2 migration victims evicted cloud-ward
    # --- cloud pool events --------------------------------------------
    cloud_dispatch: jax.Array  # matured tasks dispatched into a FaaS slot
    pool_blocked: jax.Array    # matured but parked on a saturated pool
    # --- GEMS window events -------------------------------------------
    gems_moved: jax.Array      # Alg-1 reschedules moved to the cloud
    gems_withheld: jax.Array   # blocked purely by the GEMS-B winnability gate
    # --- edge executor events -----------------------------------------
    edge_exec: jax.Array       # tasks started on the edge executor
    # --- drops by cause -----------------------------------------------
    drop_infeasible: jax.Array  # JIT/feasibility drops (edge head, cloud
    #                             dispatch re-check, rejected cloud offers)
    drop_unstolen: jax.Array    # steal-only parked tasks that expired (§5.3)
    drop_qfull: jax.Array       # lost to a full edge or cloud queue
    drop_crash: jax.Array       # edge-queue tasks flushed by an edge crash
    drop_timeout: jax.Array     # parked cloud tasks past cloud_give_up_ms
    # --- cross-edge events (filled between ticks by the scan body) ----
    peer_out: jax.Array        # tasks exported to a peer edge
    peer_in: jax.Array         # tasks imported from a peer edge
    # --- per-model outcome deltas (exactly the summary stats' ticks) --
    hit: jax.Array             # i32[M] deadline hits (n_success delta)
    miss: jax.Array            # i32[M] deadline misses (n_miss delta)
    drop: jax.Array            # i32[M] drops, all causes (n_drop delta)
    stolen: jax.Array          # i32[M] §5.3 steals (n_stolen delta)
    # --- utility deltas -----------------------------------------------
    qos: jax.Array             # f32[] QoS utility earned this tick
    qoe: jax.Array             # f32[] QoE utility earned this tick
    # --- end-of-tick gauges -------------------------------------------
    eq_depth: jax.Array        # edge-queue occupancy
    cq_depth: jax.Array        # cloud-queue occupancy
    slots_busy: jax.Array      # FaaS slots still busy at tick end
    valid: jax.Array           # bool[] this (tick, edge) cell is live
    # --- per-task tail evidence ---------------------------------------
    slack_hist: jax.Array      # i32[B] deadline slack of successful tasks
    latency_hist: jax.Array    # i32[B] arrival→completion latency, successes


# TickCounters leaves that are per-tick *event* counts: zeroed on padded
# (valid=False) cells.  Everything else is a gauge or outcome delta that
# must keep the reverted state's value for exact ledger accounting.
EVENT_FIELDS = (
    "arrivals", "admit_edge", "admit_cloud", "migrated", "cloud_dispatch",
    "pool_blocked", "gems_moved", "gems_withheld", "edge_exec",
    "drop_infeasible", "drop_unstolen", "drop_qfull", "drop_crash",
    "drop_timeout", "peer_out", "peer_in", "slack_hist", "latency_hist")


def zero_counters(n_models: int, spec: TraceSpec) -> TickCounters:
    """A fresh all-zero per-edge accumulator for one tick."""
    zi = jnp.zeros((), jnp.int32)
    zm = jnp.zeros(n_models, jnp.int32)
    zb = jnp.zeros(spec.hist_bins, jnp.int32)
    return TickCounters(
        arrivals=zi, admit_edge=zi, admit_cloud=zi, migrated=zi,
        cloud_dispatch=zi, pool_blocked=zi, gems_moved=zi, gems_withheld=zi,
        edge_exec=zi, drop_infeasible=zi, drop_unstolen=zi, drop_qfull=zi,
        drop_crash=zi, drop_timeout=zi,
        peer_out=zi, peer_in=zi,
        hit=zm, miss=zm, drop=zm, stolen=zm,
        qos=jnp.zeros(()), qoe=jnp.zeros(()),
        eq_depth=zi, cq_depth=zi, slots_busy=zi,
        valid=jnp.zeros((), bool),
        slack_hist=zb, latency_hist=zb)


def hist_counts(values: jax.Array, mask: jax.Array,
                spec: TraceSpec) -> jax.Array:
    """Bucket ``values[mask]`` into the spec's fixed bins → ``i32[B]``.

    Bin ``k`` covers ``[k·w, (k+1)·w)`` with ``w = hist_max_ms / bins``;
    negatives clamp into bin 0 and overflow into the last bin, so the
    total count is always ``mask.sum()`` (percentile math stays exact on
    counts, approximate only in value, by at most one bin width).
    """
    values = jnp.atleast_1d(values)
    mask = jnp.atleast_1d(mask)
    scale = spec.hist_bins / spec.hist_max_ms
    idx = jnp.clip((values * scale).astype(jnp.int32), 0,
                   spec.hist_bins - 1)
    return jax.ops.segment_sum(mask.astype(jnp.int32), idx,
                               num_segments=spec.hist_bins)


def resolve_spec(trace, record_trace: bool = False) -> TraceSpec:
    """Normalize the public API's trace arguments to one TraceSpec.

    ``record_trace=True`` is the deprecated pre-flight-recorder alias
    for ``TraceSpec(t_hat=True)``; an explicit ``trace`` wins.
    """
    if trace is None:
        return TraceSpec(t_hat=True) if record_trace else TraceSpec()
    if not isinstance(trace, TraceSpec):
        raise TypeError(f"trace must be a TraceSpec, got {type(trace)!r}")
    return trace
