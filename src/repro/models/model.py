"""Model assembly for the 10 assigned architectures.

One :class:`Model` per :class:`ArchConfig` exposes:

* ``init(rng)``             → parameter pytree (blocks stacked for scan)
* ``param_specs()``         → matching pytree of *logical axis* tuples
* ``forward(params, batch)``→ (logits, aux) full-sequence (training/prefill)
* ``loss(params, batch)``   → scalar LM loss (+ MoE router aux)
* ``init_cache(batch, max_seq)`` → decode cache pytree
* ``prefill(params, batch, max_seq)`` → (last logits, cache)
* ``decode_step(params, cache, token, pos)`` → (logits, cache)

Layers are scanned (``lax.scan`` over stacked params) with optional remat,
so even nemotron's 96 layers trace as one block.  Families:

dense — pre-norm GQA + MLP.                     moe — GQA + top-k experts.
vlm   — dense decoder over [patch; text] embeds. encdec — whisper enc-dec.
ssm   — xLSTM (7 mLSTM : 1 sLSTM groups).        hybrid — Mamba2 groups
with a single *shared* attention+MLP block applied every ``attn_every``
layers (Zamba2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.sharding import shard
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL

# ---------------------------------------------------------------------------
# parameter definition tables:  name → (shape, logical axes)
# ---------------------------------------------------------------------------


def _attn_defs(cfg: ArchConfig, prefix: str = "") -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        f"{prefix}wq": ((d, h, hd), ("embed_fsdp", "heads", "head_dim")),
        f"{prefix}wk": ((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
        f"{prefix}wv": ((d, kv, hd), ("embed_fsdp", "kv_heads", "head_dim")),
        f"{prefix}wo": ((h, hd, d), ("heads", "head_dim", "embed_fsdp")),
    }
    if cfg.qkv_bias:
        p.update({
            f"{prefix}bq": ((h, hd), ("heads", "head_dim")),
            f"{prefix}bk": ((kv, hd), ("kv_heads", "head_dim")),
            f"{prefix}bv": ((kv, hd), ("kv_heads", "head_dim"))})
    return p


def _mlp_defs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "silu":
        return {"wg": ((d, f), ("embed_fsdp", "mlp")),
                "wu": ((d, f), ("embed_fsdp", "mlp")),
                "wd": ((f, d), ("mlp", "embed_fsdp"))}
    return {"wi": ((d, f), ("embed_fsdp", "mlp")),
            "wd": ((f, d), ("mlp", "embed_fsdp"))}


def _dense_block_defs(cfg: ArchConfig) -> dict:
    return {"ln1": ((cfg.d_model,), (None,)),
            "ln2": ((cfg.d_model,), (None,)),
            **_attn_defs(cfg), **_mlp_defs(cfg)}


def _moe_block_defs(cfg: ArchConfig) -> dict:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    p = {"ln1": ((d,), (None,)), "ln2": ((d,), (None,)),
         **_attn_defs(cfg),
         "router": ((d, e), ("embed_fsdp", "experts"))}
    sp = cfg.expert_split
    if sp > 1:
        # split-expert layout: (E·s, D, Fe/s) with the merged expert dim
        # on the model axis — D stays whole, so the expert GEMMs need no
        # per-layer fsdp all-gather (grok §Perf iteration)
        e2, f2 = e * sp, fe // sp
        up_ax = ("experts", "embed_fsdp", "mlp")
        if cfg.act == "silu":
            p.update({"we_g": ((e2, d, f2), up_ax),
                      "we_u": ((e2, d, f2), up_ax)})
        else:
            p.update({"we_i": ((e2, d, f2), up_ax)})
        p["we_d"] = ((e2, f2, d), ("experts", "mlp", "embed_fsdp"))
        return p
    if cfg.act == "silu":
        p.update({"we_g": ((e, d, fe), ("experts", "embed_fsdp", "mlp")),
                  "we_u": ((e, d, fe), ("experts", "embed_fsdp", "mlp"))})
    else:
        p.update({"we_i": ((e, d, fe), ("experts", "embed_fsdp", "mlp"))})
    p["we_d"] = ((e, fe, d), ("experts", "mlp", "embed_fsdp"))
    return p


def _mamba_block_defs(cfg: ArchConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pin = 2 * di + 2 * n + h
    return {"ln": ((d,), (None,)),
            "w_in": ((d, pin), ("embed_fsdp", "ssm_inner")),
            "dt_bias": ((h,), (None,)),
            "a_log": ((h,), (None,)),
            "d_skip": ((h,), (None,)),
            "w_out": ((di, d), ("ssm_inner", "embed_fsdp"))}


def _mlstm_block_defs(cfg: ArchConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    return {"ln": ((d,), (None,)),
            "wq": ((d, di), ("embed_fsdp", "ssm_inner")),
            "wk": ((d, di), ("embed_fsdp", "ssm_inner")),
            "wv": ((d, di), ("embed_fsdp", "ssm_inner")),
            "w_gate": ((d, 2 * cfg.n_heads), ("embed_fsdp", None)),
            "w_out": ((di, d), ("ssm_inner", "embed_fsdp"))}


def _slstm_block_defs(cfg: ArchConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    pd = d // h
    return {"ln": ((d,), (None,)),
            "w_in": ((d, d), ("embed_fsdp", None)),
            "w_rec": ((h, 2 * pd, 4 * pd), ("heads", None, None)),
            "b_rec": ((h, 4 * pd), ("heads", None)),
            "w_out": ((d, d), (None, "embed_fsdp"))}


def _encdec_dec_defs(cfg: ArchConfig) -> dict:
    return {"ln1": ((cfg.d_model,), (None,)),
            "ln2": ((cfg.d_model,), (None,)),
            "ln3": ((cfg.d_model,), (None,)),
            **_attn_defs(cfg), **_attn_defs(cfg, prefix="x_"),
            **_mlp_defs(cfg)}


def _init_from_defs(rng, defs: dict, n: Optional[int], dtype) -> dict:
    """Initialize one (or ``n`` stacked) block(s) from a def table."""
    out = {}
    keys = jax.random.split(rng, len(defs))
    for k, (name, (shape, _)) in zip(keys, sorted(defs.items())):
        full = (n, *shape) if n else shape
        if name.startswith(("ln", "d_skip")) or name == "dt_bias":
            val = jnp.ones(full, dtype) if name.startswith(
                ("ln", "d_skip")) else jnp.zeros(full, dtype)
        elif name == "a_log":
            val = jnp.zeros(full, dtype)       # A = −1 per head
        elif name.startswith("b"):
            val = jnp.zeros(full, dtype)
        else:
            fan_in = np.prod(shape[:-1]) if len(shape) > 1 else shape[0]
            val = (jax.random.normal(k, full) / np.sqrt(fan_in)).astype(dtype)
        out[name] = val
    return out


def _specs_from_defs(defs: dict, stacked: bool) -> dict:
    return {name: ((None, *ax) if stacked else ax)
            for name, (_, ax) in defs.items()}


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def _remat_policy(name: str):
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(name)


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.pdtype = jnp.dtype(cfg.param_dtype)
        # embedding/lm-head padded to a multiple of 256 so the vocab dim is
        # always tensor-parallelizable (granite's 49155 = 3 × 16385 would
        # otherwise replicate 13 GB of fp32 softmax per device); pad logits
        # are masked to −inf in unembed() so they never win or leak prob.
        self.vpad = -(-cfg.vocab // 256) * 256

    # -- structure ------------------------------------------------------
    def _layout(self) -> dict:
        """family → {group_name: (defs, stack_count)}"""
        cfg = self.cfg
        if cfg.family in ("dense", "vlm"):
            lay = {"blocks": (_dense_block_defs(cfg), cfg.n_layers)}
            if cfg.family == "vlm":
                lay["vis_proj"] = ({"w": ((cfg.d_model, cfg.d_model),
                                          ("embed_fsdp", None))}, None)
            return lay
        if cfg.family == "moe":
            return {"blocks": (_moe_block_defs(cfg), cfg.n_layers)}
        if cfg.family == "encdec":
            return {"enc_blocks": (_dense_block_defs(cfg), cfg.enc_layers),
                    "enc_norm": ({"scale": ((cfg.d_model,), (None,))}, None),
                    "blocks": (_encdec_dec_defs(cfg), cfg.n_layers)}
        if cfg.family == "ssm":     # xLSTM
            g, rem = divmod(cfg.n_layers, cfg.slstm_every)
            assert rem == 0, "xlstm layers must divide slstm_every"
            return {"mlstm": (_mlstm_block_defs(cfg),
                              g * (cfg.slstm_every - 1)),
                    "slstm": (_slstm_block_defs(cfg), g)}
        if cfg.family == "hybrid":  # Zamba2
            g = cfg.n_layers // cfg.attn_every
            tail = cfg.n_layers - g * cfg.attn_every
            lay = {"mamba": (_mamba_block_defs(cfg), g * cfg.attn_every),
                   "shared_attn": (_dense_block_defs(cfg), None)}
            if tail:
                lay["mamba_tail"] = (_mamba_block_defs(cfg), tail)
            return lay
        raise ValueError(cfg.family)

    def init(self, rng) -> dict:
        cfg = self.cfg
        rngs = jax.random.split(rng, 8)
        params = {
            "embed": (jax.random.normal(rngs[0], (self.vpad, cfg.d_model))
                      * 0.02).astype(self.pdtype),
            "final_norm": jnp.ones((cfg.d_model,), self.pdtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(rngs[1], (cfg.d_model, self.vpad))
                / np.sqrt(cfg.d_model)).astype(self.pdtype)
        for i, (name, (defs, n)) in enumerate(sorted(self._layout().items())):
            params[name] = _init_from_defs(rngs[2 + i], defs, n, self.pdtype)
        return params

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs = {"embed": ("vocab", "embed_fsdp"),
                 "final_norm": (None,)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = ("embed_fsdp", "vocab")
        for name, (defs, n) in self._layout().items():
            specs[name] = _specs_from_defs(defs, stacked=n is not None)
        return specs

    # -- shared pieces ---------------------------------------------------
    def _scan(self, body, carry, xs):
        """lax.scan over stacked layers, or an unrolled Python loop when
        cfg.unroll_layers (roofline delta method — see launch/dryrun.py)."""
        if not self.cfg.unroll_layers:
            return jax.lax.scan(body, carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        outs = []
        for i in range(n):
            carry, out = body(carry, jax.tree.map(lambda a: a[i], xs))
            outs.append(out)
        if outs and jax.tree.structure(outs[0]).num_leaves == 0:
            return carry, None
        stacked = jax.tree.map(lambda *os: jnp.stack(os), *outs)
        return carry, stacked

    def _maybe_remat(self, fn):
        if self.cfg.remat:
            return jax.checkpoint(fn,
                                  policy=_remat_policy(
                                      self.cfg.remat_policy))
        return fn

    def _dense_block(self, p, x, *, causal=True, window=None,
                     use_rope=True):
        cfg = self.cfg
        h = L.attention_block(p, cfg, L.rms_norm(x, p["ln1"], cfg.norm_eps),
                              causal=causal, window=window, use_rope=use_rope)
        x = x + h
        x = x + L.mlp(p, cfg, L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return shard(x, "batch", "act_seq", "embed")

    def _moe_block(self, p, x):
        cfg = self.cfg
        h = L.attention_block(p, cfg, L.rms_norm(x, p["ln1"], cfg.norm_eps))
        x = x + h
        y, aux = MOE.moe_mlp(p, cfg, L.rms_norm(x, p["ln2"], cfg.norm_eps))
        return shard(x + y, "batch", "act_seq", "embed"), aux

    # -- forward (training / prefill logits) ------------------------------
    def embed_tokens(self, params, tokens):
        x = params["embed"][tokens].astype(self.dtype)
        return shard(x, "batch", "act_seq", "embed")

    def unembed(self, params, x):
        w = params.get("lm_head")
        if w is None:
            w = params["embed"].T
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(self.dtype))
        logits = shard(logits, "batch", "seq", "vocab")
        if self.vpad != self.cfg.vocab:      # mask padding columns
            logits = jnp.where(jnp.arange(self.vpad) < self.cfg.vocab,
                               logits, -1e30)
        return logits[..., : self.cfg.vocab] if False else logits

    def forward(self, params, batch):
        cfg = self.cfg
        fam = cfg.family
        if fam in ("dense", "vlm"):
            x = self.embed_tokens(params, batch["tokens"])
            if fam == "vlm":
                img = batch["patches"].astype(self.dtype) @ \
                    params["vis_proj"]["w"]
                x = jnp.concatenate([img, x], axis=1)
            blk = self._maybe_remat(lambda p, h: self._dense_block(p, h))
            def body(h, p):
                return blk(p, h), None
            x, _ = self._scan(body, x, params["blocks"])
            if fam == "vlm":
                x = x[:, cfg.n_image_tokens:]
            aux = jnp.zeros((), jnp.float32)
        elif fam == "moe":
            x = self.embed_tokens(params, batch["tokens"])
            blk = self._maybe_remat(lambda p, h: self._moe_block(p, h))
            def body(carry, p):
                h, aux = carry
                h, a = blk(p, h)
                return (h, aux + a), None
            (x, aux), _ = self._scan(
                body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
        elif fam == "encdec":
            enc = self._encode(params, batch["frames"])
            x = self.embed_tokens(params, batch["tokens"])
            blk = self._maybe_remat(
                lambda p, h, e: self._decdec_block(p, h, e))
            def body(h, p):
                return blk(p, h, enc), None
            x, _ = self._scan(body, x, params["blocks"])
            aux = jnp.zeros((), jnp.float32)
        elif fam == "ssm":
            x, aux = self._xlstm_forward(params, batch)
        elif fam == "hybrid":
            x, aux = self._zamba_forward(params, batch)
        else:
            raise ValueError(fam)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self.unembed(params, x), aux

    def _encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(self.dtype)
        x = shard(x, "batch", "frames", "embed")
        blk = self._maybe_remat(
            lambda p, h: self._dense_block(p, h, causal=False, window=0))
        def body(h, p):
            return blk(p, h), None
        x, _ = self._scan(body, x, params["enc_blocks"])
        return L.rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)

    def _decdec_block(self, p, x, enc):
        cfg = self.cfg
        h = L.attention_block(p, cfg, L.rms_norm(x, p["ln1"], cfg.norm_eps))
        x = x + h
        x = x + self._cross_attend(p, L.rms_norm(x, p["ln2"], cfg.norm_eps),
                                   enc)
        x = x + L.mlp(p, cfg, L.rms_norm(x, p["ln3"], cfg.norm_eps))
        return shard(x, "batch", "act_seq", "embed")

    def _cross_attend(self, p, x, enc):
        cfg = self.cfg
        q = jnp.einsum("bsd,dhk->bshk", x, p["x_wq"])
        k = jnp.einsum("bfd,dhk->bfhk", enc, p["x_wk"])
        v = jnp.einsum("bfd,dhk->bfhk", enc, p["x_wv"])
        out = L.attend(q, k, v, causal=False, window=0)
        return jnp.einsum("bshk,hkd->bsd", out, p["x_wo"])

    def _xlstm_forward(self, params, batch):
        cfg = self.cfg
        x = self.embed_tokens(params, batch["tokens"])
        g = cfg.n_layers // cfg.slstm_every
        per = cfg.slstm_every - 1
        m_params = jax.tree.map(
            lambda a: a.reshape(g, per, *a.shape[1:]), params["mlstm"])

        def mblk(p, h):
            y, _ = XL.mlstm_parallel(p, cfg, L.rms_norm(h, p["ln"],
                                                        cfg.norm_eps))
            return shard(h + y, "batch", "act_seq", "embed")

        def sblk(p, h):
            y, _ = XL.slstm_scan(p, cfg, L.rms_norm(h, p["ln"],
                                                    cfg.norm_eps))
            return shard(h + y, "batch", "act_seq", "embed")

        mblk_r = self._maybe_remat(mblk)
        sblk_r = self._maybe_remat(sblk)

        def group(h, ps):
            mp, sp = ps
            def inner(hh, p):
                return mblk_r(p, hh), None
            h, _ = self._scan(inner, h, mp)
            return sblk_r(sp, h), None

        x, _ = self._scan(group, x, (m_params, params["slstm"]))
        return x, jnp.zeros((), jnp.float32)

    def _zamba_forward(self, params, batch):
        cfg = self.cfg
        x = self.embed_tokens(params, batch["tokens"])
        g = cfg.n_layers // cfg.attn_every
        m_params = jax.tree.map(
            lambda a: a.reshape(g, cfg.attn_every, *a.shape[1:]),
            params["mamba"])

        def mamba_blk(p, h):
            y, _ = SSM.ssd_chunked(p, cfg, L.rms_norm(h, p["ln"],
                                                      cfg.norm_eps))
            return shard(h + y, "batch", "act_seq", "embed")

        mamba_r = self._maybe_remat(mamba_blk)
        shared = self._maybe_remat(
            lambda p, h: self._dense_block(p, h, window=cfg.sliding_window))

        def group(h, mp):
            def inner(hh, p):
                return mamba_r(p, hh), None
            h, _ = self._scan(inner, h, mp)
            return shared(params["shared_attn"], h), None

        x, _ = self._scan(group, x, m_params)
        if "mamba_tail" in params:
            def inner(hh, p):
                return mamba_r(p, hh), None
            x, _ = self._scan(inner, x, params["mamba_tail"])
        return x, jnp.zeros((), jnp.float32)

    # -- loss -------------------------------------------------------------
    def loss(self, params, batch):
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0) + aux

    # ======================================================================
    # decoding
    # ======================================================================
    def init_cache(self, batch_size: int, max_seq: int) -> dict:
        cfg = self.cfg
        dt = self.dtype
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            return L.init_kv_cache(cfg, cfg.n_layers, batch_size, max_seq, dt)
        if fam == "encdec":
            c = L.init_kv_cache(cfg, cfg.n_layers, batch_size, max_seq, dt)
            c["xk"] = jnp.zeros((cfg.n_layers, batch_size, cfg.n_frames,
                                 cfg.n_kv_heads, cfg.hd), dt)
            c["xv"] = jnp.zeros_like(c["xk"])
            return c
        if fam == "ssm":
            g = cfg.n_layers // cfg.slstm_every
            per = cfg.slstm_every - 1
            h, pd = cfg.n_heads, cfg.d_inner // cfg.n_heads
            spd = cfg.d_model // cfg.n_heads
            return {
                "m_c": jnp.zeros((g, per, batch_size, h, pd, pd), dt),
                "m_n": jnp.zeros((g, per, batch_size, h, pd), dt),
                "s_h": jnp.zeros((g, batch_size, h, spd), dt),
                "s_c": jnp.zeros((g, batch_size, h, spd), jnp.float32),
                "s_n": jnp.zeros((g, batch_size, h, spd), jnp.float32),
            }
        if fam == "hybrid":
            g = cfg.n_layers // cfg.attn_every
            tail = cfg.n_layers - g * cfg.attn_every
            h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            w = L.cache_width(cfg, max_seq)
            c = {"state": jnp.zeros((g, cfg.attn_every, batch_size, h, pd,
                                     n), dt),
                 "k": jnp.zeros((g, batch_size, w, cfg.n_kv_heads, cfg.hd),
                                dt),
                 "v": jnp.zeros((g, batch_size, w, cfg.n_kv_heads, cfg.hd),
                                dt)}
            if tail:
                c["tail_state"] = jnp.zeros((tail, batch_size, h, pd, n), dt)
            return c
        raise ValueError(fam)

    def decode_step(self, params, cache: dict, token: jax.Array,
                    pos: jax.Array, frames: Optional[jax.Array] = None):
        """One serve step: next-token logits for ``token`` at ``pos``.

        token: (B, 1) int32; pos: scalar int32 (same position across the
        batch — continuous batching handled by the serve engine).
        """
        cfg = self.cfg
        fam = cfg.family
        x = self.embed_tokens(params, token)
        w = cfg.sliding_window
        if fam in ("dense", "vlm", "moe"):
            # cache rides the scan CARRY and is updated in place per layer:
            # passing it as xs/ys makes XLA double-buffer the whole cache
            # (~2 extra cache copies in temps at 32k contexts)
            nl = cfg.n_layers

            def body(carry, xs):
                h, ck_all, cv_all = carry
                p, i = xs
                ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0,
                                                  keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0,
                                                  keepdims=False)
                h, ck, cv = self._decode_attn_block(p, h, ck, cv, pos,
                                                    fam == "moe")
                ck_all = jax.lax.dynamic_update_index_in_dim(
                    ck_all, ck, i, 0)
                cv_all = jax.lax.dynamic_update_index_in_dim(
                    cv_all, cv, i, 0)
                return (h, ck_all, cv_all), None
            (x, ck, cv), _ = self._scan(
                body, (x, cache["k"], cache["v"]),
                (params["blocks"], jnp.arange(nl)))
            cache = {"k": ck, "v": cv}
        elif fam == "encdec":
            def body(carry, xs):
                h, ck_all, cv_all = carry
                p, i, xk, xv = xs
                ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0,
                                                  keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0,
                                                  keepdims=False)
                h, ck, cv = self._decode_self_attn(p, h, ck, cv, pos)
                q = jnp.einsum("bsd,dhk->bshk",
                               L.rms_norm(h, p["ln2"], cfg.norm_eps),
                               p["x_wq"])
                out = L.decode_attend(q, xk, xv, pos=xk.shape[1] - 1,
                                      window=0)
                h = h + jnp.einsum("bshk,hkd->bsd", out, p["x_wo"])
                h = h + L.mlp(p, cfg, L.rms_norm(h, p["ln3"], cfg.norm_eps))
                ck_all = jax.lax.dynamic_update_index_in_dim(
                    ck_all, ck, i, 0)
                cv_all = jax.lax.dynamic_update_index_in_dim(
                    cv_all, cv, i, 0)
                return (h, ck_all, cv_all), None
            (x, ck, cv), _ = self._scan(
                body, (x, cache["k"], cache["v"]),
                (params["blocks"], jnp.arange(cfg.n_layers), cache["xk"],
                 cache["xv"]))
            cache = dict(cache, k=ck, v=cv)
        elif fam == "ssm":
            x, cache = self._xlstm_decode(params, cache, x)
        elif fam == "hybrid":
            x, cache = self._zamba_decode(params, cache, x, pos)
        else:
            raise ValueError(fam)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self.unembed(params, x), cache

    def _decode_self_attn(self, p, x, ck, cv, pos):
        """Self-attention sublayer against a per-layer KV cache slice."""
        cfg = self.cfg
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        b = x.shape[0]
        positions = jnp.broadcast_to(pos, (b, 1))
        q, k, v = L.qkv_proj(p, cfg, h, positions)
        w = cfg.sliding_window
        from repro.launch.sharding import current_mesh
        if cfg.opt_decode and current_mesh() is not None:
            out, ck, cv = L.decode_update_attend_sharded(
                cfg, q, k, v, ck, cv, pos, w)
            return x + jnp.einsum("bshk,hkd->bsd", out, p["wo"]), ck, cv
        wsz = ck.shape[1]
        slot = pos % wsz if w else jnp.minimum(pos, wsz - 1)
        ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
        if cfg.attn_impl == "pallas" and not w:
            # flash-decode kernel: contiguous caches only (the ring-buffer
            # validity mask of SWA caches stays on the jnp path)
            from repro.kernels import ops
            lengths = jnp.full((b,), pos + 1, jnp.int32)
            out = ops.decode_attention(
                q[:, 0], ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
                lengths)[:, None]
        else:
            out = L.decode_attend(q, ck, cv, pos=pos, window=w)
        return x + jnp.einsum("bshk,hkd->bsd", out, p["wo"]), ck, cv

    def _decode_attn_block(self, p, x, ck, cv, pos, is_moe: bool):
        """Pre-norm attention block against a per-layer KV cache slice."""
        cfg = self.cfg
        x, ck, cv = self._decode_self_attn(p, x, ck, cv, pos)
        hh = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if is_moe:
            y, _ = MOE.moe_mlp(p, cfg, hh)
        else:
            y = L.mlp(p, cfg, hh)
        return x + y, ck, cv

    def _xlstm_decode(self, params, cache, x):
        cfg = self.cfg
        g = cfg.n_layers // cfg.slstm_every
        per = cfg.slstm_every - 1
        m_params = jax.tree.map(
            lambda a: a.reshape(g, per, *a.shape[1:]), params["mlstm"])

        def group(h, xs):
            mp, sp, mc, mn, sh, sc, sn = xs
            def inner(carry, ys):
                hh = carry
                p, c1, n1 = ys
                y, (c2, n2) = XL.mlstm_decode_step(
                    p, cfg, L.rms_norm(hh, p["ln"], cfg.norm_eps), (c1, n1))
                return hh + y, (c2, n2)
            h, (mc, mn) = self._scan(inner, h, (mp, mc, mn))
            y, (sh, sc, sn) = XL.slstm_decode_step(
                sp, cfg, L.rms_norm(h, sp["ln"], cfg.norm_eps),
                (sh, sc, sn))
            return h + y, (mc, mn, sh, sc, sn)

        x, (mc, mn, sh, sc, sn) = self._scan(
            group, x, (m_params, params["slstm"], cache["m_c"],
                       cache["m_n"], cache["s_h"], cache["s_c"],
                       cache["s_n"]))
        return x, {"m_c": mc, "m_n": mn, "s_h": sh, "s_c": sc, "s_n": sn}

    def _zamba_decode(self, params, cache, x, pos):
        cfg = self.cfg
        g = cfg.n_layers // cfg.attn_every
        m_params = jax.tree.map(
            lambda a: a.reshape(g, cfg.attn_every, *a.shape[1:]),
            params["mamba"])

        def group(carry, xs):
            h, ck_all, cv_all = carry
            mp, st, i = xs
            def inner(carry2, ys):
                hh = carry2
                p, s1 = ys
                y, s2 = SSM.ssd_decode_step(
                    p, cfg, L.rms_norm(hh, p["ln"], cfg.norm_eps), s1)
                return hh + y, s2
            h, st = self._scan(inner, h, (mp, st))
            ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
            h, ck, cv = self._decode_attn_block(
                params["shared_attn"], h, ck, cv, pos, False)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
            return (h, ck_all, cv_all), st

        g_count = g
        (x, ck, cv), st = self._scan(
            group, (x, cache["k"], cache["v"]),
            (m_params, cache["state"], jnp.arange(g_count)))
        new = dict(cache, state=st, k=ck, v=cv)
        if "tail_state" in cache:
            def inner(carry, ys):
                hh = carry
                p, s1 = ys
                y, s2 = SSM.ssd_decode_step(
                    p, cfg, L.rms_norm(hh, p["ln"], cfg.norm_eps), s1)
                return hh + y, s2
            x, ts = self._scan(inner, x,
                                 (params["mamba_tail"], cache["tail_state"]))
            new["tail_state"] = ts
        return x, new

    # -- prefill -----------------------------------------------------------
    def prefill(self, params, batch, max_seq: int):
        """Run the full prompt, build the decode cache, return last logits.

        Implemented as forward + per-layer K/V recomputation for attention
        families (clarity over speed on CPU; the Pallas flash kernel is the
        TPU fast path), and a stateful scan for SSM/hybrid.
        """
        cfg = self.cfg
        fam = cfg.family
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = self.init_cache(b, max_seq)
        if fam in ("dense", "vlm", "moe", "encdec"):
            # forward while capturing K/V per layer
            x = self.embed_tokens(params, tokens)
            if fam == "vlm":
                img = batch["patches"].astype(self.dtype) @ \
                    params["vis_proj"]["w"]
                x = jnp.concatenate([img, x], axis=1)
            enc = self._encode(params, batch["frames"]) \
                if fam == "encdec" else None
            positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                         (b, x.shape[1]))

            s_total = x.shape[1]
            w_cache = cache["k"].shape[2]
            emit_from = max(0, s_total - min(w_cache, s_total))

            def body(h, p):
                hn = L.rms_norm(h, p["ln1"], cfg.norm_eps)
                q, k, v = L.qkv_proj(p, cfg, hn, positions)
                out = L.attend_auto(q, k, v, causal=True,
                                    window=cfg.sliding_window,
                                    unroll=cfg.unroll_layers)
                h = h + jnp.einsum("bshk,hkd->bsd", out, p["wo"])
                if fam == "encdec":
                    xk = jnp.einsum("bfd,dhk->bfhk", enc, p["x_wk"])
                    xv = jnp.einsum("bfd,dhk->bfhk", enc, p["x_wv"])
                    qx = jnp.einsum("bsd,dhk->bshk",
                                    L.rms_norm(h, p["ln2"], cfg.norm_eps),
                                    p["x_wq"])
                    ox = L.attend(qx, xk, xv, causal=False, window=0)
                    h = h + jnp.einsum("bshk,hkd->bsd", ox, p["x_wo"])
                    h = h + L.mlp(p, cfg, L.rms_norm(h, p["ln3"],
                                                     cfg.norm_eps))
                    k_out = shard(k[:, emit_from:], "batch", "kv_seq",
                                  "kv_heads", None)
                    v_out = shard(v[:, emit_from:], "batch", "kv_seq",
                                  "kv_heads", None)
                    return h, (k_out, v_out, xk, xv)
                hh = L.rms_norm(h, p["ln2"], cfg.norm_eps)
                if fam == "moe":
                    y, _ = MOE.moe_mlp(p, cfg, hh)
                else:
                    y = L.mlp(p, cfg, hh)
                k_out = shard(k[:, emit_from:], "batch", "kv_seq",
                              "kv_heads", None)
                v_out = shard(v[:, emit_from:], "batch", "kv_seq",
                              "kv_heads", None)
                return h + y, (k_out, v_out)

            x, kvs = self._scan(body, x, params["blocks"])
            if fam == "encdec":
                ks, vs, xk, xv = kvs
                cache["xk"], cache["xv"] = xk, xv
            else:
                ks, vs = kvs
            ks = shard(ks, None, "batch", "kv_seq", "kv_heads", None)
            vs = shard(vs, None, "batch", "kv_seq", "kv_heads", None)
            w = cache["k"].shape[2]
            seq_total = x.shape[1]
            take = min(w, seq_total)
            if cfg.sliding_window and take == w:
                # ring placement: slot of absolute position p is p % w
                slots = jnp.arange(seq_total - take, seq_total) % w
                cache["k"] = jnp.zeros_like(cache["k"]).at[:, :, slots].set(
                    ks)
                cache["v"] = jnp.zeros_like(cache["v"]).at[:, :, slots].set(
                    vs)
            elif take == w:
                cache["k"], cache["v"] = ks, vs      # no copy
            else:
                cache["k"] = cache["k"].at[:, :, :take].set(ks)
                cache["v"] = cache["v"].at[:, :, :take].set(vs)
            if fam == "vlm":
                x = x[:, cfg.n_image_tokens:]
            x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
            logits = self.unembed(params, x[:, -1:])
            return logits, cache
        # SSM / hybrid: run the chunked scans, keep final states
        if fam == "ssm":
            logits, cache = self._xlstm_prefill(params, tokens, cache)
            return logits, cache
        if fam == "hybrid":
            return self._zamba_prefill(params, tokens, cache, max_seq)
        raise ValueError(fam)

    def _xlstm_prefill(self, params, tokens, cache):
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)
        g = cfg.n_layers // cfg.slstm_every
        per = cfg.slstm_every - 1
        m_params = jax.tree.map(
            lambda a: a.reshape(g, per, *a.shape[1:]), params["mlstm"])

        def group(h, xs):
            mp, sp = xs
            def inner(hh, p):
                y, st = XL.mlstm_parallel(
                    p, cfg, L.rms_norm(hh, p["ln"], cfg.norm_eps))
                return hh + y, st
            h, (mc, mn) = self._scan(inner, h, mp)
            y, (sh, sc, sn) = XL.slstm_scan(
                sp, cfg, L.rms_norm(h, sp["ln"], cfg.norm_eps))
            return h + y, (mc, mn, sh, sc, sn)

        x, (mc, mn, sh, sc, sn) = self._scan(
            group, x, (m_params, params["slstm"]))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.unembed(params, x[:, -1:])
        return logits, {"m_c": mc, "m_n": mn, "s_h": sh, "s_c": sc,
                        "s_n": sn}

    def _zamba_prefill(self, params, tokens, cache, max_seq):
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)
        b, s = tokens.shape
        g = cfg.n_layers // cfg.attn_every
        m_params = jax.tree.map(
            lambda a: a.reshape(g, cfg.attn_every, *a.shape[1:]),
            params["mamba"])
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        w = cache["k"].shape[2]
        take = min(w, s)
        slots = (jnp.arange(s - take, s) % w) if cfg.sliding_window \
            else jnp.arange(take)

        def group(h, xs):
            mp = xs
            def inner(carry, p):
                hh = carry
                y, st = SSM.ssd_chunked(
                    p, cfg, L.rms_norm(hh, p["ln"], cfg.norm_eps))
                return hh + y, st
            h, st = self._scan(inner, h, mp)
            p = params["shared_attn"]
            hn = L.rms_norm(h, p["ln1"], cfg.norm_eps)
            q, k, v = L.qkv_proj(p, cfg, hn, positions)
            out = L.attend_auto(q, k, v, causal=True,
                                window=cfg.sliding_window,
                                unroll=cfg.unroll_layers)
            h = h + jnp.einsum("bshk,hkd->bsd", out, p["wo"])
            h = h + L.mlp(p, cfg, L.rms_norm(h, p["ln2"], cfg.norm_eps))
            return h, (st, k[:, s - take:], v[:, s - take:])

        x, (st, ks, vs) = self._scan(group, x, m_params)
        cache["state"] = st
        cache["k"] = cache["k"].at[:, :, slots].set(ks)
        cache["v"] = cache["v"].at[:, :, slots].set(vs)
        if "tail_state" in cache:
            def inner(carry, p):
                hh = carry
                y, stt = SSM.ssd_chunked(
                    p, cfg, L.rms_norm(hh, p["ln"], cfg.norm_eps))
                return hh + y, stt
            x, ts = self._scan(inner, x, params["mamba_tail"])
            cache["tail_state"] = ts
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self.unembed(params, x[:, -1:]), cache
