"""Mamba2-style selective state-space block (SSD), training + decode.

Training/prefill uses the chunkwise-parallel SSD formulation (intra-chunk
quadratic attention-like term + inter-chunk recurrence over chunk states),
which keeps the computation matmul-heavy for the MXU; decoding is the O(1)
recurrent state update.  The depthwise conv of the reference implementation
is folded away (identity) — noted in DESIGN.md — since it contributes <1 %
of FLOPs and no distribution-relevant structure.

Shapes: heads H = d_inner/ssm_head_dim, head dim P = ssm_head_dim,
state N = cfg.ssm_state.  State cache per layer: (B, H, P, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import shard

CHUNK = 128


def _split_in_proj(p: dict, cfg: ArchConfig, x: jax.Array):
    """x (B,S,D) → z,xs (B,S,H,P), B,C (B,S,N), dt (B,S,H)."""
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = x @ p["w_in"]                 # (B,S, 2*di + 2*n + h)
    z, xs, bmat, cmat, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    b, s, _ = x.shape
    z = z.reshape(b, s, h, cfg.ssm_head_dim)
    xs = xs.reshape(b, s, h, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt + p["dt_bias"])          # (B,S,H) > 0
    return z, xs, bmat, cmat, dt


def ssd_chunked(p: dict, cfg: ArchConfig, x: jax.Array,
                state: jax.Array | None = None):
    """Chunkwise-parallel SSD scan over the full sequence.

    Returns (y (B,S,D_inner→D via out proj), final_state (B,H,P,N)).
    """
    b, s, _ = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, bmat, cmat, dt = _split_in_proj(p, cfg, x)
    a = -jnp.exp(p["a_log"])                         # (H,) negative decay

    nc = max(1, s // CHUNK)
    c = s // nc
    assert nc * c == s, f"seq {s} not divisible by chunk {c}"

    # reshape into chunks
    xs_c = xs.reshape(b, nc, c, h, pd)
    b_c = bmat.reshape(b, nc, c, n)
    c_c = cmat.reshape(b, nc, c, n)
    dt_c = dt.reshape(b, nc, c, h)

    # per-step log decay  ℓ_t = a·dt_t  (per head)
    ldec = dt_c * a[None, None, None, :]             # (B,nc,c,H) ≤ 0
    cum = jnp.cumsum(ldec, axis=2)                   # within-chunk cumsum

    # intra-chunk (causal "attention" with decay):  for i ≥ j:
    #   M[i,j] = exp(cum_i − cum_j) · (C_i·B_j) · dt_j
    ci = cum[:, :, :, None, :]                       # (B,nc,c,1,H)
    cj = cum[:, :, None, :, :]                       # (B,nc,1,c,H)
    decay = jnp.exp(jnp.clip(ci - cj, -60.0, 0.0))   # (B,nc,c,c,H)
    decay = shard(decay, "batch", None, None, None, "ssm_heads")
    causal = jnp.tril(jnp.ones((c, c), bool))
    cb = jnp.einsum("bgin,bgjn->bgij", c_c, b_c)     # (B,nc,c,c)
    m = cb[..., None] * decay * dt_c[:, :, None, :, :]
    m = jnp.where(causal[None, None, :, :, None], m, 0.0)
    m = shard(m, "batch", None, None, None, "ssm_heads")
    y_intra = jnp.einsum("bgijh,bgjhp->bgihp", m, xs_c)

    # chunk summaries: S_g = Σ_j exp(cum_end − cum_j) dt_j B_j x_j
    tail = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0))
    sum_g = jnp.einsum("bgjh,bgjn,bgjhp->bghpn",
                       tail * dt_c, b_c, xs_c)       # (B,nc,H,P,N)
    sum_g = shard(sum_g, "batch", None, "ssm_heads", None, None)
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0))  # (B,nc,H)

    # inter-chunk recurrence over chunk states
    def scan_fn(carry, inp):
        s_sum, dec = inp                              # (B,H,P,N), (B,H)
        new = carry * dec[:, :, None, None] + s_sum
        return new, carry                             # emit state *before*

    init = state if state is not None else jnp.zeros((b, h, pd, n), x.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn, init.astype(jnp.float32),
        (jnp.moveaxis(sum_g, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)     # (B,nc,H,P,N)

    # contribution of the carried state:  y_t += C_t · (decay_to_t · S_prev)
    into = jnp.exp(jnp.clip(cum, -60.0, 0.0))         # decay from chunk start
    y_inter = jnp.einsum("bgin,bgih,bghpn->bgihp",
                         c_c, into, prev_states.astype(x.dtype))

    y = (y_intra + y_inter).reshape(b, s, h, pd)
    y = y + xs * p["d_skip"][None, None, :, None]     # D skip connection
    y = y * jax.nn.silu(z)                            # gated output
    y = shard(y, "batch", "seq", "ssm_inner", None)
    out = y.reshape(b, s, cfg.d_inner) @ p["w_out"]
    return out, final.astype(x.dtype)


def ssd_decode_step(p: dict, cfg: ArchConfig, x: jax.Array,
                    state: jax.Array):
    """One-token recurrent update.  x: (B,1,D); state: (B,H,P,N)."""
    z, xs, bmat, cmat, dt = _split_in_proj(p, cfg, x)
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt[:, 0, :] * a[None, :])           # (B,H)
    # state ← decay·state + dt·x_t ⊗ B_t
    upd = jnp.einsum("bhp,bn,bh->bhpn", xs[:, 0], bmat[:, 0], dt[:, 0])
    state = state * dec[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], state)  # C_t · state
    y = y + xs[:, 0] * p["d_skip"][None, :, None]
    y = (y * jax.nn.silu(z[:, 0]))[:, None]            # (B,1,H,P)
    b = x.shape[0]
    out = y.reshape(b, 1, cfg.d_inner) @ p["w_out"]
    return out, state
