"""Shared model layers: norms, RoPE, activations, MLPs, GQA attention.

Everything is a pure function over explicit parameter dicts; activations
carry logical sharding annotations via :func:`repro.launch.sharding.shard`
(no-ops outside a rules context).  Attention supports full-causal and
sliding-window (banded) masks, encoder (bidirectional) use, and single-token
decode against a (possibly ring-buffered) KV cache.
"""
from __future__ import annotations

import inspect
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import resolves, shard

try:
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map
# the replication-check kwarg was renamed check_rep → check_vma
_SHARD_MAP_CHECK_KW = ("check_vma" if "check_vma"
                       in inspect.signature(_shard_map).parameters
                       else "check_rep")


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "sq_relu":                       # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: (..., S, H, hd), positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    act = activation(cfg.act)
    if cfg.act == "silu":                      # gated (SwiGLU-style)
        h = act(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = act(x @ p["wi"])
    # keep the token dim sharded when the arch cannot head-shard (llava,
    # starcoder2): otherwise the gather replicates MLP compute 16×
    seq_ax = "seq" if resolves(cfg.n_heads, "heads") else "act_seq"
    h = shard(h, "batch", seq_ax, "mlp")
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KV, hd) → (B, S, KV*groups, hd) for GQA."""
    if groups == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)
                            ).reshape(b, s, kv * groups, hd)


def qkv_proj(p: dict, cfg: ArchConfig, x: jax.Array, positions,
             use_rope: bool = True) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # when heads cannot take the model axis (llava 56H, starcoder2 24H)
    # keep the *sequence* sharded instead — otherwise the projections
    # replicate over the whole model axis (16× compute per device)
    q_seq = "seq" if resolves(q.shape[2], "heads") else "act_seq"
    kv_seq_ax = "seq" if resolves(k.shape[2], "kv_heads") else "act_seq"
    q = shard(q, "batch", q_seq, "heads", "head_dim")
    k = shard(k, "batch", kv_seq_ax, "kv_heads", "head_dim")
    v = shard(v, "batch", kv_seq_ax, "kv_heads", "head_dim")
    return q, k, v


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           causal: bool, window: int = 0,
           q_offset: int = 0) -> jax.Array:
    """Reference attention (B, Sq, H, hd) × (B, Sk, KV, hd) → (B, Sq, H, hd).

    ``window`` > 0 applies a sliding-window band; ``q_offset`` is the
    absolute position of q[0] relative to k[0] (for chunked prefill).
    """
    groups = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, groups), _repeat_kv(v, groups)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    # big intermediate: shard heads over 'model' (or q-seq when heads are
    # not divisible — llava 56H, starcoder2 24H; 'used' tracking picks one)
    logits = shard(logits, "batch", "heads", "seq_model", None)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", probs, v)
    seq_ax = "seq" if resolves(q.shape[2], "heads") else "act_seq"
    return shard(out, "batch", seq_ax, "heads", "head_dim")


CHUNK_Q_THRESHOLD = 16_384
# §Perf iteration: 2048-row chunks halve the fp32 chunk-logits working set
# vs 4096 (llava prefill 16.3 → 12.8 GB/dev, fits HBM); 1024 gave <2 %
# more (KV emission dominates beyond this) — diminishing returns reached.
CHUNK_Q = 2_048


def attend_pallas(q, k, v, *, causal: bool, window: int = 0) -> jax.Array:
    """Route through the Pallas flash-attention kernel (kernels/).

    Layout adapters only: (B,S,H,hd) ↔ the kernel's (B,H,S,hd)/(B,KV,S,hd).
    Interpret-mode on CPU; Mosaic on TPU.
    """
    from repro.kernels import ops
    bq = min(128, q.shape[1])
    out = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        block_q=bq, block_k=bq)
    return out.transpose(0, 2, 1, 3)


def attend_auto(q, k, v, *, causal: bool, window: int = 0,
                unroll: bool = False, impl: str = "ref") -> jax.Array:
    """attend(), q-chunked above 16k tokens so the (Sq, Sk) logits never
    materialize (≈15 GB/device for llava at 32k otherwise).

    ``unroll=True`` expands the chunk loop in Python — used by the roofline
    delta method, where ``lax.scan`` bodies would be cost-counted once.
    ``impl="pallas"`` dispatches to the flash-attention kernel.
    """
    if impl == "pallas":
        return attend_pallas(q, k, v, causal=causal, window=window)
    b, s, h, hd = q.shape
    if s < CHUNK_Q_THRESHOLD:
        return attend(q, k, v, causal=causal, window=window)
    # pad queries up to a CHUNK_Q multiple (llava's 32768+2880 image
    # prefix): padded rows attend like ordinary tokens and are dropped —
    # keeping chunks 4096-aligned so the seq_model sharding divides
    s_pad = -(-s // CHUNK_Q) * CHUNK_Q
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    nc = s_pad // CHUNK_Q
    cq = CHUNK_Q
    if unroll:
        outs = [attend(q[:, i * cq:(i + 1) * cq], k, v,
                       causal=causal, window=window, q_offset=i * cq)
                for i in range(nc)]
        return jnp.concatenate(outs, axis=1)[:, :s]
    qc = jnp.moveaxis(q.reshape(b, nc, cq, h, hd), 1, 0)

    def body(_, xs):
        off, qi = xs
        return None, attend(qi, k, v, causal=causal, window=window,
                            q_offset=off)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nc) * cq, qc))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s_pad, h, hd)
    return out[:, :s]


def attention_block(p: dict, cfg: ArchConfig, x: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    positions: Optional[jax.Array] = None,
                    use_rope: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = qkv_proj(p, cfg, x, positions, use_rope)
    w = cfg.sliding_window if window is None else window
    out = attend_auto(q, k, v, causal=causal, window=w,
                      unroll=cfg.unroll_layers, impl=cfg.attn_impl)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# KV cache (contiguous or ring-buffered for sliding windows)
# ---------------------------------------------------------------------------

def cache_width(cfg: ArchConfig, max_seq: int) -> int:
    """Sliding-window archs only ever hold `window` keys (sub-linear at
    500k context); full attention holds the whole sequence."""
    return min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq


def init_kv_cache(cfg: ArchConfig, n_layers: int, batch: int, max_seq: int,
                  dtype) -> dict:
    w = cache_width(cfg, max_seq)
    shape = (n_layers, batch, w, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_update(cache_k, cache_v, layer: int, k: jax.Array, v: jax.Array,
                 pos: jax.Array, window: int) -> tuple[jax.Array, jax.Array]:
    """Write one token's K/V at ``pos`` (ring-buffered if window > 0)."""
    w = cache_k.shape[2]
    slot = pos % w if window else jnp.minimum(pos, w - 1)
    ck = jax.lax.dynamic_update_slice(
        cache_k[layer], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache_v[layer], v, (0, slot, 0, 0))
    return cache_k.at[layer].set(ck), cache_v.at[layer].set(cv)


def decode_attend(q: jax.Array, ck: jax.Array, cv: jax.Array, *,
                  pos: jax.Array, window: int) -> jax.Array:
    """Single-token attention over the cache.

    q: (B, 1, H, hd); ck/cv: (B, W, KV, hd); ``pos`` is the absolute
    position of the new token (its K/V already written to the cache).

    GQA is computed with the query heads grouped per KV head — the KV
    cache is never re-materialized ``groups``× (that repeat dominated
    decode temps for nemotron's 12-way GQA at 32k context).
    """
    b, one, h, hd = q.shape
    kv = ck.shape[2]
    groups = h // kv
    qg = q.reshape(b, kv, groups, hd)        # query heads per KV head
    scale = hd ** -0.5
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, ck).astype(
        jnp.float32) * scale                  # (B, KV, G, W)
    w = ck.shape[1]
    slots = jnp.arange(w)
    if window:
        # ring buffer: slot s holds absolute position p_s = pos−((pos−s)%w),
        # automatically causal and within the window; it is valid iff it
        # has been written at all, i.e. p_s ≥ 0.
        valid = (pos - slots) % w <= pos
    else:
        valid = slots <= pos
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    logits = shard(logits, "batch", "kv_heads", None, "kv_seq")
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cv)    # (B, KV, G, hd)
    return out.reshape(b, 1, h, hd)


# ---------------------------------------------------------------------------
# §Perf: shard_map flash-decode (beyond-paper optimization)
# ---------------------------------------------------------------------------

def decode_update_attend_sharded(cfg: ArchConfig, q, k_new, v_new, ck, cv,
                                 pos, window: int):
    """Cache update + single-token attention with the cache *sequence*
    dimension explicitly sharded over the ``model`` axis.

    The GSPMD baseline re-gathers the whole per-layer cache at every
    ``dynamic_update_slice`` (the write slot crosses shard boundaries) —
    the "involuntary full rematerialization" XLA warns about, ≈0.2 GB per
    layer per step.  Here each model shard owns a contiguous cache slice:
    the owner writes the new K/V locally, every shard computes a partial
    online-softmax (flash-decode), and the combine is a pmax/psum of
    (B, KV, G)-sized partials — bytes per step drop from O(cache) to
    O(q).

    q: (B, 1, H, hd); k_new/v_new: (B, 1, KV, hd); ck/cv: (B, W, KV, hd).
    Returns (out (B, 1, H, hd), ck, cv).
    """
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import current_mesh

    mesh = current_mesh()
    b, _, h, hd = q.shape
    kv = ck.shape[2]
    groups = h // kv
    w = ck.shape[1]
    batch_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_ax = batch_ax if b % _prod(mesh.shape[a] for a in batch_ax) == 0 \
        else ()
    n_model = mesh.shape["model"]
    seq_ax = "model" if w % n_model == 0 else None
    bspec = batch_ax or None

    qs = P(bspec, None, None, None)
    kvnew = P(bspec, None, None, None)
    cache_spec = P(bspec, seq_ax, None, None)

    def body(q_l, kn_l, vn_l, ck_l, cv_l):
        w_loc = ck_l.shape[1]
        if seq_ax:
            my_lo = jax.lax.axis_index("model") * w_loc
        else:
            my_lo = 0
        slot_g = pos % w if window else jnp.minimum(pos, w - 1)
        slot_l = jnp.clip(slot_g - my_lo, 0, w_loc - 1)
        mine = (slot_g >= my_lo) & (slot_g < my_lo + w_loc)
        ck_new = jax.lax.dynamic_update_slice(ck_l, kn_l, (0, slot_l, 0, 0))
        cv_new = jax.lax.dynamic_update_slice(cv_l, vn_l, (0, slot_l, 0, 0))
        ck_l = jnp.where(mine, ck_new, ck_l)
        cv_l = jnp.where(mine, cv_new, cv_l)

        bl = q_l.shape[0]
        qg = q_l.reshape(bl, kv, groups, hd)
        logits = jnp.einsum("bkgd,bskd->bkgs", qg, ck_l).astype(
            jnp.float32) * hd ** -0.5                  # (B, KV, G, W_loc)
        slots = my_lo + jnp.arange(w_loc)
        if window:
            valid = (pos - slots) % w <= pos
        else:
            valid = slots <= pos
        logits = jnp.where(valid[None, None, None, :], logits, -1e30)
        m_loc = logits.max(axis=-1)                    # (B, KV, G)
        if seq_ax:
            m = jax.lax.pmax(m_loc, "model")
        else:
            m = m_loc
        p_ = jnp.exp(logits - m[..., None])
        p_ = jnp.where(valid[None, None, None, :], p_, 0.0)
        l_loc = p_.sum(axis=-1)
        o_loc = jnp.einsum("bkgs,bskd->bkgd", p_.astype(q_l.dtype), cv_l)
        if seq_ax:
            l = jax.lax.psum(l_loc, "model")
            o = jax.lax.psum(o_loc.astype(jnp.float32), "model")
        else:
            l, o = l_loc, o_loc.astype(jnp.float32)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q_l.dtype)
        return out.reshape(bl, 1, h, hd), ck_l, cv_l

    out, ck, cv = _shard_map(
        body, mesh=mesh,
        in_specs=(qs, kvnew, kvnew, cache_spec, cache_spec),
        out_specs=(qs, cache_spec, cache_spec),
        **{_SHARD_MAP_CHECK_KW: False},
    )(q, k_new, v_new, ck, cv)
    return out, ck, cv


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out
