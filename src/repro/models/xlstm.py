"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, strictly recurrent) — arXiv:2405.04517.

mLSTM is a gated linear-attention cell: per head, memory C ∈ R^{P×P}
updated as  C_t = f_t·C_{t−1} + i_t·(v_t k_tᵀ),  n_t = f_t·n_{t−1} + i_t·k_t,
read  h_t = (C_t q_t) / max(|n_tᵀ q_t|, 1).  We run it chunk-parallel like
SSD (matmul-heavy for the MXU).  sLSTM's recurrence is inherently
sequential (exponential gating with a normalizer/stabilizer state) and is
implemented with ``lax.scan`` over time.

State caches: mLSTM (B, H, P, P) + (B, H, P); sLSTM (B, H, P) × 3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import resolves, shard

CHUNK = 256   # larger chunks: the (P,P) matrix summaries dominate memory


def _heads(cfg: ArchConfig) -> tuple[int, int]:
    h = cfg.n_heads
    return h, cfg.d_inner // h        # (heads, per-head dim P)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_parallel(p: dict, cfg: ArchConfig, x: jax.Array,
                   state: tuple | None = None):
    """Full-sequence chunk-parallel mLSTM.  x: (B,S,D) → (B,S,D)."""
    b, s, d = x.shape
    h, pd = _heads(cfg)
    q = (x @ p["wq"]).reshape(b, s, h, pd)
    k = (x @ p["wk"]).reshape(b, s, h, pd) * pd ** -0.5
    v = (x @ p["wv"]).reshape(b, s, h, pd)
    gates = x @ p["w_gate"]                          # (B,S,2H)
    logi, logf = jnp.split(gates, 2, axis=-1)
    logf = jax.nn.log_sigmoid(logf.astype(jnp.float32))   # (B,S,H) ≤ 0
    logi = logi.astype(jnp.float32)

    nc = max(1, s // CHUNK)
    c = s // nc
    assert nc * c == s
    qc = q.reshape(b, nc, c, h, pd)
    kc = k.reshape(b, nc, c, h, pd)
    vc = v.reshape(b, nc, c, h, pd)
    fi = logf.reshape(b, nc, c, h)
    ii = logi.reshape(b, nc, c, h)
    cumf = jnp.cumsum(fi, axis=2)

    # intra-chunk: M[i,j] = exp(cumf_i − cumf_j + i_j) for j ≤ i
    expo = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + \
        ii[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((c, c), bool))[None, None, :, :, None]
    m = jnp.where(causal, jnp.exp(jnp.clip(expo, -60.0, 30.0)), 0.0)
    qk = jnp.einsum("bgihp,bgjhp->bgijh", qc, kc)
    w = (m * qk).astype(x.dtype)                 # gated linear attention
    y_intra_v = jnp.einsum("bgijh,bgjhp->bgihp", w, vc)
    n_q = w.sum(axis=3)                          # q·(Σ_j M[i,j] k_j)

    # chunk summaries for the recurrence
    tail = jnp.exp(jnp.clip(cumf[:, :, -1:, :] - cumf + ii, -60.0, 30.0))
    c_sum = jnp.einsum("bgjh,bgjhp,bgjhq->bghpq", tail, vc, kc)  # (B,nc,H,P,P)
    c_sum = shard(c_sum, "batch", None, None, "ssm_inner", None)
    n_sum = jnp.einsum("bgjh,bgjhp->bghp", tail, kc)
    cdec = jnp.exp(jnp.clip(cumf[:, :, -1, :], -60.0, 0.0))      # (B,nc,H)

    if state is None:
        c0 = jnp.zeros((b, h, pd, pd), jnp.float32)
        n0 = jnp.zeros((b, h, pd), jnp.float32)
    else:
        c0, n0 = (state[0].astype(jnp.float32), state[1].astype(jnp.float32))

    def scan_fn(carry, inp):
        cm, nm = carry
        cs, ns, dec = inp
        new_c = cm * dec[:, :, None, None] + cs
        new_n = nm * dec[:, :, None] + ns
        return (new_c, new_n), (cm, nm)

    (cf, nf), (c_prev, n_prev) = jax.lax.scan(
        scan_fn, (c0, n0),
        (jnp.moveaxis(c_sum, 1, 0).astype(jnp.float32),
         jnp.moveaxis(n_sum, 1, 0).astype(jnp.float32),
         jnp.moveaxis(cdec, 1, 0).astype(jnp.float32)))
    c_prev = jnp.moveaxis(c_prev, 0, 1)          # (B,nc,H,P,P) state at start
    c_prev = shard(c_prev, "batch", None, None, "ssm_inner", None)
    n_prev = jnp.moveaxis(n_prev, 0, 1)

    into = jnp.exp(jnp.clip(cumf, -60.0, 0.0))   # decay chunk-start → i
    y_inter = jnp.einsum("bgih,bghpq,bgihq->bgihp",
                         into, c_prev.astype(x.dtype) * 1.0, qc)
    n_inter = jnp.einsum("bgih,bghp,bgihp->bgih",
                         into, n_prev.astype(x.dtype) * 1.0, qc)

    num = (y_intra_v + y_inter).reshape(b, s, h, pd)
    den = (n_q + n_inter).reshape(b, s, h)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y_seq = "seq" if resolves(h, "heads") else "act_seq"
    y = shard(y.astype(x.dtype), "batch", y_seq, "heads", None)
    out = y.reshape(b, s, cfg.d_inner) @ p["w_out"]
    return out, (cf.astype(x.dtype), nf.astype(x.dtype))


def mlstm_decode_step(p: dict, cfg: ArchConfig, x: jax.Array, state):
    """One-token mLSTM update.  x: (B,1,D)."""
    b = x.shape[0]
    h, pd = _heads(cfg)
    cm, nm = state
    q = (x @ p["wq"]).reshape(b, h, pd)
    k = (x @ p["wk"]).reshape(b, h, pd) * pd ** -0.5
    v = (x @ p["wv"]).reshape(b, h, pd)
    gates = (x @ p["w_gate"]).reshape(b, 2 * h)
    logi, logf = jnp.split(gates, 2, axis=-1)
    f = jnp.exp(jax.nn.log_sigmoid(logf.astype(jnp.float32)))
    i = jnp.exp(jnp.clip(logi.astype(jnp.float32), -60.0, 30.0))
    cm = cm * f[..., None, None] + i[..., None, None] * \
        jnp.einsum("bhp,bhq->bhpq", v, k)
    nm = nm * f[..., None] + i[..., None] * k
    num = jnp.einsum("bhpq,bhq->bhp", cm, q)
    den = jnp.einsum("bhp,bhp->bh", nm, q)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    out = y.reshape(b, 1, cfg.d_inner).astype(x.dtype) @ p["w_out"]
    return out, (cm.astype(x.dtype), nm.astype(x.dtype))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_cell(p, h_prev, c_prev, n_prev, xt):
    """One sLSTM step for all heads.  Shapes: (B, H, P)."""
    b, hh, pd = h_prev.shape
    inp = jnp.concatenate([xt.reshape(b, hh, pd), h_prev], axis=-1)
    zifo = jnp.einsum("bhp,hpq->bhq", inp, p["w_rec"]) + p["b_rec"]
    z, i, f, o = jnp.split(zifo, 4, axis=-1)        # (B,H,P) each
    z = jnp.tanh(z)
    i = jnp.exp(jnp.clip(i.astype(jnp.float32), -60.0, 20.0))
    f = jnp.exp(jax.nn.log_sigmoid(f.astype(jnp.float32)))
    o = jax.nn.sigmoid(o)
    c = f * c_prev + i * z.astype(jnp.float32)
    n = f * n_prev + i
    h = o * (c / jnp.maximum(n, 1.0)).astype(o.dtype)
    return h, c, n


def slstm_scan(p: dict, cfg: ArchConfig, x: jax.Array,
               state: tuple | None = None):
    """Sequential sLSTM over the sequence.  x: (B,S,D) → (B,S,D)."""
    b, s, d = x.shape
    h, pd = cfg.n_heads, d // cfg.n_heads
    xt = x @ p["w_in"]                               # (B,S,D)
    if state is None:
        h0 = jnp.zeros((b, h, pd), x.dtype)
        c0 = jnp.zeros((b, h, pd), jnp.float32)
        n0 = jnp.zeros((b, h, pd), jnp.float32)
    else:
        h0, c0, n0 = state

    def step(carry, x_t):
        hp, cp, np_ = carry
        hn, cn, nn = _slstm_cell(p, hp, cp, np_, x_t)
        return (hn, cn, nn), hn

    (hf, cf, nf), ys = jax.lax.scan(step, (h0, c0, n0),
                                    jnp.moveaxis(xt, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d)
    out = y @ p["w_out"]
    return out, (hf, cf, nf)


def slstm_decode_step(p: dict, cfg: ArchConfig, x: jax.Array, state):
    b, _, d = x.shape
    xt = (x @ p["w_in"])[:, 0]
    h0, c0, n0 = state
    hn, cn, nn = _slstm_cell(p, h0, c0, n0, xt)
    out = hn.reshape(b, 1, d) @ p["w_out"]
    return out, (hn, cn, nn)
