"""Mixture-of-Experts layer: top-k router with group-wise capacity dispatch.

Tokens are reshaped into ``cfg.moe_groups`` groups (the launcher sets this
to the data-parallel shard count; 1 on CPU tests) and dispatch — stable
sort by expert, rank-within-expert, capacity drop — happens *independently
per group*.  Every dispatch tensor carries the group dim, which shards over
the data axes, so the sorts, scatters and gathers never cross a device
boundary; only the expert GEMMs touch sharded weights.  This is the
standard TPU MoE layout (group-wise Switch dispatch): compiled FLOPs scale
with ``top_k · capacity_factor``, not with the expert count, and the
all-to-all happens implicitly at the (g, E, C, D) buffer resharding.

Expert weights shard expert-parallel over the ``model`` axis when divisible
(qwen3's 128 experts) and fall back to per-expert tensor parallelism on
d_ff (grok's 8 experts < 16-way axis) — the rule engine decides per tensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import shard


def router(p: dict, x_flat: jax.Array, cfg: ArchConfig):
    """Top-k routing.  Returns (weights (T,k), experts (T,k), aux_loss)."""
    logits = (x_flat.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)         # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Shazeer-style load-balance auxiliary loss
    density = jnp.mean(jax.nn.one_hot(experts[:, 0], cfg.n_experts), 0)
    mean_probs = probs.mean(0)
    aux = cfg.router_aux_coef * cfg.n_experts * jnp.sum(density * mean_probs)
    return weights.astype(x_flat.dtype), experts, aux


def capacity_dispatch(experts: jax.Array, n_experts: int, capacity: int):
    """Assign each (token, k) pair a slot in an (E, C) buffer.

    Returns (slot (T*k,), keep (T*k,)) where ``slot = e*C + rank`` for kept
    pairs; pairs past an expert's capacity are dropped.

    Rank-within-expert is computed sort-based in O(T·k) memory — a one-hot
    cumsum would materialize a (T·k, E) matrix (≈4 GB for qwen3 at 1M
    tokens).  ``argsort`` is stable, so ranks follow (token, k) order.
    """
    flat = experts.reshape(-1)                                  # (T*k,)
    tk = flat.shape[0]
    order = jnp.argsort(flat, stable=True)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(tk))
    counts = jax.ops.segment_sum(jnp.ones_like(flat), flat,
                                 num_segments=n_experts)
    start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                             jnp.cumsum(counts)[:-1]])
    sorted_rank = jnp.arange(tk) - start[flat[order]]
    rank = sorted_rank[inv]
    keep = rank < capacity
    slot = flat * capacity + jnp.minimum(rank, capacity - 1)
    return slot, keep


def _dispatch_group(xg: jax.Array, experts, cfg: ArchConfig, capacity: int):
    """One group's (E, C, D) buffer + combine metadata — all local ops."""
    t, d = xg.shape
    k = cfg.top_k
    slot, keep = capacity_dispatch(experts, cfg.n_experts, capacity)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    # extra trash row so dropped pairs never clobber a real slot
    buf = jnp.zeros((cfg.n_experts * capacity + 1, d), xg.dtype)
    buf = buf.at[jnp.where(keep, slot, cfg.n_experts * capacity)].set(
        xg[tok_idx])
    return buf[:-1].reshape(cfg.n_experts, capacity, d), slot, keep, tok_idx


def _combine_group(out, slot, keep, tok_idx, weights, t: int):
    gathered = out.reshape(-1, out.shape[-1])[slot] * \
        (weights.reshape(-1, 1) * keep[:, None])
    return jax.ops.segment_sum(gathered, tok_idx, num_segments=t)


def moe_mlp(p: dict, cfg: ArchConfig, x: jax.Array):
    """(B, S, D) → (B, S, D), plus the router aux loss."""
    b, s, d = x.shape
    t = b * s
    g = max(1, cfg.moe_groups)
    while t % g:                      # tiny smoke batches: shrink groups
        g //= 2
    tg = t // g
    xf = shard(x.reshape(g, tg, d), "moe_grp", None, None)
    capacity = int(tg * cfg.top_k / cfg.n_experts * cfg.capacity_factor) + 1

    weights, experts, aux = jax.vmap(lambda xg: router(p, xg, cfg))(xf)
    aux = aux.mean()

    buf, slot, keep, tok_idx = jax.vmap(
        lambda xg, eg: _dispatch_group(xg, eg, cfg, capacity))(xf, experts)
    buf = shard(buf, "moe_grp", "experts", None, None)    # (g, E, C, D)

    sp = cfg.expert_split
    if sp > 1:
        # split-expert GEMMs: weights (E·s, D, Fe/s) viewed (E, s, D, F2);
        # the s-partials of the down projection sum inside the einsum
        e = cfg.n_experts
        f2 = cfg.d_ff_expert // sp
        def view_up(w):
            return w.reshape(e, sp, d, f2)
        if cfg.act == "silu":
            h = jax.nn.silu(jnp.einsum("gecd,esdf->gescf", buf,
                                       view_up(p["we_g"]))) * \
                jnp.einsum("gecd,esdf->gescf", buf, view_up(p["we_u"]))
        else:
            h = jax.nn.gelu(jnp.einsum("gecd,esdf->gescf", buf,
                                       view_up(p["we_i"])))
        wd = p["we_d"].reshape(e, sp, f2, d)
        out = jnp.einsum("gescf,esfd->gecd", h, wd)
        out = shard(out, "moe_grp", "experts", None, None)
    else:
        if cfg.act == "silu":
            h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["we_g"])) * \
                jnp.einsum("gecd,edf->gecf", buf, p["we_u"])
        else:
            h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf, p["we_i"]))
        h = shard(h, "moe_grp", "experts", None, "mlp")
        out = jnp.einsum("gecf,efd->gecd", h, p["we_d"])
        out = shard(out, "moe_grp", "experts", None, None)

    y = jax.vmap(_combine_group, in_axes=(0, 0, 0, 0, 0, None))(
        out, slot, keep, tok_idx, weights, tg)
    y = shard(y, "moe_grp", None, None)
    return y.reshape(b, s, d).astype(x.dtype), aux
