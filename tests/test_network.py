"""Trace-shaping tests for sim/network.py (§8.5, Figs 1–2)."""
import numpy as np
import pytest

from repro.sim.network import (NOMINAL_BW_MBPS, SEGMENT_KB,
                               CloudLatencyModel, cellular_bandwidth_trace,
                               constant, transfer_ms, trapezium)


# ---------------------------------------------------------------------------
# trapezium θ(t)
# ---------------------------------------------------------------------------

def test_trapezium_breakpoints_default():
    th = trapezium()
    assert th(0.0) == 0.0
    assert th(59_999.9) == 0.0
    assert th(60_000.0) == 0.0            # ramp starts at low
    assert th(75_000.0) == pytest.approx(200.0)
    assert th(90_000.0) == 400.0          # plateau begins
    assert th(150_000.0) == 400.0
    assert th(210_000.0) == 400.0         # ramp-down start
    assert th(225_000.0) == pytest.approx(200.0)
    assert th(240_000.0) == 0.0           # back to low, stays there
    assert th(1e9) == 0.0


def test_trapezium_custom_levels_and_monotone_ramps():
    th = trapezium(low=50.0, high=250.0, ramp_up=(10_000.0, 20_000.0),
                   ramp_down=(30_000.0, 40_000.0))
    assert th(0.0) == 50.0
    assert th(25_000.0) == 250.0
    up = [th(t) for t in np.linspace(10_000.0, 20_000.0, 11)]
    down = [th(t) for t in np.linspace(30_000.0, 40_000.0, 11)]
    assert all(a <= b + 1e-9 for a, b in zip(up, up[1:]))
    assert all(a >= b - 1e-9 for a, b in zip(down, down[1:]))
    assert min(up + down) >= 50.0 and max(up + down) <= 250.0


# ---------------------------------------------------------------------------
# bounded bandwidth random walk
# ---------------------------------------------------------------------------

def test_bandwidth_walk_stays_within_bounds():
    lo, hi = 0.25, 40.0
    bw = cellular_bandwidth_trace(seed=7, duration_ms=120_000.0,
                                  lo=lo, hi=hi)
    samples = [bw(t) for t in np.arange(0.0, 125_000.0, 250.0)]
    assert min(samples) >= lo
    assert max(samples) <= hi
    assert np.std(samples) > 0.0          # it actually moves


def test_bandwidth_walk_reproducible_and_seeded_at_start():
    a = cellular_bandwidth_trace(seed=3, duration_ms=10_000.0)
    b = cellular_bandwidth_trace(seed=3, duration_ms=10_000.0)
    assert [a(t) for t in range(0, 10_000, 500)] == \
        [b(t) for t in range(0, 10_000, 500)]
    # the walk is anchored: bw(0) is exactly `start`, not a perturbed step
    assert a(0.0) == 18.0
    assert cellular_bandwidth_trace(seed=9, start=5.0)(0.0) == 5.0
    # out-of-range start values are clipped to the walk's bounds
    assert cellular_bandwidth_trace(seed=9, hi=40.0, start=99.0)(0.0) == 40.0


def test_bandwidth_walk_wraps_past_horizon():
    bw = cellular_bandwidth_trace(seed=3, duration_ms=10_000.0,
                                  step_ms=1_000.0)
    period = 11_000.0                      # n = duration/step + 1 samples
    for t in (0.0, 1_500.0, 9_999.0):
        assert bw(t + period) == bw(t)     # periodic extension, not a pin
        assert bw(t + 5 * period) == bw(t)


def test_traces_are_array_native():
    ts = np.array([0.0, 75_000.0, 150_000.0, 500_000.0])
    th = trapezium()
    np.testing.assert_allclose(th(ts), [th(float(t)) for t in ts])
    assert constant(7.0)(ts).shape == ts.shape
    bw = cellular_bandwidth_trace(seed=3)
    np.testing.assert_allclose(bw(ts), [bw(float(t)) for t in ts])


# ---------------------------------------------------------------------------
# transfer_ms edge cases
# ---------------------------------------------------------------------------

def test_transfer_ms_nominal_segment():
    # 38 kB at 20 Mbps: 38·8/20 = 15.2 ms
    assert transfer_ms(SEGMENT_KB, NOMINAL_BW_MBPS) == pytest.approx(15.2)


def test_transfer_ms_degenerate_inputs():
    assert transfer_ms(0.0, 10.0) == 0.0
    # zero / negative bandwidth clamps to 1e-3 Mbps instead of dividing by 0
    assert transfer_ms(1.0, 0.0) == pytest.approx(8_000.0)
    assert transfer_ms(1.0, -5.0) == pytest.approx(8_000.0)
    # monotone: more bandwidth, less time
    assert transfer_ms(38.0, 40.0) < transfer_ms(38.0, 20.0)


def test_shaped_delta_combines_theta_and_signed_bandwidth_penalty():
    cm = CloudLatencyModel(latency_at=constant(100.0),
                           bandwidth_at=constant(NOMINAL_BW_MBPS / 2))
    want_bw = transfer_ms(SEGMENT_KB, NOMINAL_BW_MBPS / 2) - \
        transfer_ms(SEGMENT_KB, NOMINAL_BW_MBPS)
    assert want_bw > 0
    assert cm.shaped_delta(0.0) == pytest.approx(100.0 + want_bw)
    # signed convention: bandwidth above nominal *speeds transfers up*,
    # floored at recovering the full nominal transfer cost
    cm2 = CloudLatencyModel(latency_at=constant(7.0),
                            bandwidth_at=constant(2 * NOMINAL_BW_MBPS))
    gain = transfer_ms(SEGMENT_KB, 2 * NOMINAL_BW_MBPS) - \
        transfer_ms(SEGMENT_KB, NOMINAL_BW_MBPS)
    assert gain < 0
    assert cm2.shaped_delta(0.0) == pytest.approx(7.0 + gain)
    cm3 = CloudLatencyModel(bandwidth_at=constant(1e9))
    assert cm3.shaped_delta(0.0) >= -transfer_ms(SEGMENT_KB, NOMINAL_BW_MBPS)
    # nominal bandwidth ⇒ exactly zero penalty (the fleet's elastic limit)
    assert CloudLatencyModel(bandwidth_at=constant(
        NOMINAL_BW_MBPS)).shaped_delta(0.0) == 0.0


def test_fleet_bandwidth_penalty_matches_oracle_convention():
    from repro.sim.network import bandwidth_penalty_ms
    for mbps in (0.3, 2.0, NOMINAL_BW_MBPS, 40.0):
        want = CloudLatencyModel(
            bandwidth_at=constant(mbps)).shaped_delta(0.0)
        assert bandwidth_penalty_ms(mbps) == pytest.approx(want)
    assert bandwidth_penalty_ms(NOMINAL_BW_MBPS) == 0.0
