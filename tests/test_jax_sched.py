"""Property tests: JAX decision kernels ≡ the discrete-event oracle.

Each of the paper's scheduling decisions (feasibility, victims, Eqn-3
migration, steal selection, GEMS rescheduling, DEMS-A adaptation) is
implemented twice — as Python list code in ``sim.engine`` and as masked
``jnp`` kernels in ``core.jax_sched``.  Hypothesis drives both with random
queue states and asserts exact agreement.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the [test] extra: vendored shim
    from _minihyp import given, settings, strategies as st  # noqa: F401

from repro.core import jax_sched as js
from repro.core.schedulers import AdaptiveEstimator, make_policy
from repro.core.task import TABLE1, Task
from repro.sim.engine import Arrival, Simulator

MODELS = list(TABLE1.values())
M = len(MODELS)
GAMMA_E = jnp.array([m.gamma_edge for m in MODELS], jnp.float32)
GAMMA_C = jnp.array([m.gamma_cloud for m in MODELS], jnp.float32)
T_EDGE = jnp.array([m.t_edge for m in MODELS], jnp.float32)
T_CLOUD = jnp.array([m.t_cloud for m in MODELS], jnp.float32)
CAP = 12


def _sim(policy="DEMS"):
    arrivals = [Arrival(0.0, m) for m in MODELS]
    s = Simulator(make_policy(policy), arrivals, duration=1.0, seed=0)
    s._heap.clear()
    return s


task_st = st.tuples(st.integers(0, M - 1), st.integers(0, 300))

queue_st = st.lists(task_st, min_size=0, max_size=CAP - 2)


def _build_queue(entries, uid0=100):
    """Sorted task list (oracle) + EdgeQueue arrays (jax), identically
    ordered: stable sort by EDF key."""
    tasks = [Task(uid=uid0 + i, model=MODELS[mi], created=float(c * 10))
             for i, (mi, c) in enumerate(entries)]
    tasks.sort(key=lambda t: t.abs_deadline)   # stable → seq = position
    q = js.empty_edge_queue(CAP)
    for i, t in enumerate(tasks):
        q, ok = js.edge_push(q, t.abs_deadline, i, t.model.t_edge,
                             t.sched_deadline,
                             MODELS.index(t.model))
        assert bool(ok)
    return tasks, q


@settings(max_examples=120, deadline=None)
@given(queue_st, task_st, st.integers(0, 200), st.integers(0, 80))
def test_insert_feasibility_matches_oracle(entries, new, now10, busy10):
    now, busy = float(now10 * 10), float(busy10 * 10)
    tasks, q = _build_queue(entries)
    sim = _sim()
    sim.edge_queue = tasks
    sim.now = now
    sim.edge_busy_until = now + busy
    t_new = Task(uid=1, model=MODELS[new[0]], created=float(new[1] * 10))
    pos = sim._insert_pos(t_new)
    want = sim._feasible_at(sim.edge_queue, pos, t_new)
    got = bool(js.insert_feasible(q, now, busy, t_new.abs_deadline,
                                  t_new.model.t_edge, t_new.sched_deadline))
    assert got == want


@settings(max_examples=120, deadline=None)
@given(queue_st, task_st, st.integers(0, 200), st.integers(0, 80))
def test_victims_match_oracle(entries, new, now10, busy10):
    now, busy = float(now10 * 10), float(busy10 * 10)
    tasks, q = _build_queue(entries)
    sim = _sim()
    sim.edge_queue = tasks
    sim.now = now
    sim.edge_busy_until = now + busy
    t_new = Task(uid=1, model=MODELS[new[0]], created=float(new[1] * 10))
    pos = sim._insert_pos(t_new)
    want = {t.uid for t in sim._victims_of_insert(pos, t_new)}
    mask = np.asarray(js.victim_mask(q, now, busy, t_new.abs_deadline,
                                     t_new.model.t_edge))
    got = {tasks[i].uid for i in range(len(tasks)) if mask[i]}
    assert got == want


@settings(max_examples=120, deadline=None)
@given(queue_st, task_st, st.integers(0, 200))
def test_migration_decision_matches_oracle(entries, new, now10):
    now = float(now10 * 10)
    tasks, q = _build_queue(entries)
    if not tasks:
        return
    t_new = Task(uid=1, model=MODELS[new[0]], created=float(new[1] * 10))
    victims = tasks[: max(1, len(tasks) // 2)]
    vmask = jnp.array([t in victims for t in tasks] +
                      [False] * (CAP - len(tasks)))
    pol = make_policy("DEMS")
    want = pol.migration_decision(t_new, victims, now, lambda m: m.t_cloud)
    got = bool(js.migration_decision(
        q, vmask, now, MODELS.index(t_new.model), t_new.abs_deadline,
        GAMMA_E, GAMMA_C, T_CLOUD))
    assert got == want


cloud_task_st = st.tuples(st.integers(0, M - 1), st.integers(0, 300))


@settings(max_examples=120, deadline=None)
@given(queue_st,
       st.lists(cloud_task_st, min_size=0, max_size=CAP - 2),
       st.integers(0, 200))
def test_steal_selection_matches_oracle(entries, cloud_entries, now10):
    now = float(now10 * 10)
    tasks, q = _build_queue(entries)
    sim = _sim("DEMS")
    sim.edge_queue = list(tasks)
    sim.now = now
    sim.edge_busy_until = now          # executor idle, about to pick
    cloud_tasks = []
    cq = js.empty_cloud_queue(CAP)
    for i, (mi, c) in enumerate(cloud_entries):
        t = Task(uid=500 + i, model=MODELS[mi], created=float(c * 10))
        t.steal_only = t.model.gamma_cloud <= 0
        cloud_tasks.append(t)
        cq, ok = js.cloud_push(cq, now, t.model.t_edge, t.abs_deadline,
                               t.steal_only, t.model.steal_rank())
        assert bool(ok)
    sim.cloud_pending = list(cloud_tasks)
    want = sim._try_steal()
    got_idx = int(js.steal_select(cq, q, now, 0.0,
                                  float(sim.min_edge_t)))
    if want is None:
        assert got_idx == -1
    else:
        assert got_idx >= 0
        got = cloud_tasks[got_idx]
        # ties in (steal_only, rank) may pick a different but equal task
        assert (got.steal_only, got.model.steal_rank()) == \
            (want.steal_only, want.model.steal_rank())


@settings(max_examples=80, deadline=None)
@given(queue_st, st.integers(0, M - 1), st.integers(0, 200))
def test_gems_mask_matches_oracle(entries, lag_model, now10):
    now = float(now10 * 10)
    tasks, q = _build_queue(entries)
    sim = _sim("GEMS")
    sim.edge_queue = list(tasks)
    sim.now = now
    m = MODELS[lag_model]
    sim._gems_rescan(m)
    want = {t.uid for t in tasks if t.gems_rescheduled}
    mask = np.asarray(js.gems_reschedule_mask(
        q, now, lag_model, T_CLOUD, GAMMA_C))
    got = {tasks[i].uid for i in range(len(tasks)) if mask[i]}
    assert got == want


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(50, 2000), min_size=1, max_size=30),
       st.integers(2, 10))
def test_adaptive_observe_matches_oracle(observations, w):
    est = AdaptiveEstimator(static=400.0, w=w, eps=10.0)
    stj = js.adapt_init(jnp.array([400.0]), w=w)
    for o in observations:
        est.observe(o)
        stj = js.adapt_observe(stj, 0, o, eps=10.0)
    assert float(stj.current[0]) == pytest.approx(est.current, rel=1e-6)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.floats(0, 40_000)),
                min_size=1, max_size=25))
def test_adaptive_skip_cooling_matches_oracle(events):
    est = AdaptiveEstimator(static=400.0, w=4, eps=10.0, t_cp=10_000.0)
    stj = js.adapt_init(jnp.array([400.0]), w=4)
    for _ in range(4):
        est.observe(900.0)
        stj = js.adapt_observe(stj, 0, 900.0, eps=10.0)
    events = sorted(events, key=lambda e: e[1])
    static = jnp.array([400.0])
    for sent, t in events:
        if sent:
            est.on_sent()
            stj = js.adapt_on_sent(stj, 0)
        else:
            est.on_skip(t)
            stj = js.adapt_on_skip(stj, 0, t, static, t_cp=10_000.0)
        assert float(stj.current[0]) == pytest.approx(est.current)


adapt_event_st = st.tuples(
    st.integers(0, 1),                     # model index
    st.integers(0, 2),                     # 0 = observe, 1 = skip, 2 = sent
    st.floats(50, 2000),                   # observed duration (if observe)
    st.integers(1, 2_000))                 # time advance [ms]


@settings(max_examples=80, deadline=None)
@given(st.lists(adapt_event_st, min_size=1, max_size=40),
       st.integers(2, 8))
def test_adaptive_mixed_sequence_matches_oracle(events, w):
    """AdaptState mirrors AdaptiveEstimator step-for-step on arbitrary
    interleavings of observe / on_skip / on_sent across two models."""
    t_cp = 5_000.0
    ests = [AdaptiveEstimator(static=400.0, w=w, eps=10.0, t_cp=t_cp)
            for _ in range(2)]
    static = jnp.array([400.0, 400.0])
    stj = js.adapt_init(static, w=w)
    now = 0.0
    for m, kind, val, dt_ms in events:
        now += float(dt_ms)
        if kind == 0:
            ests[m].observe(val)
            stj = js.adapt_observe(stj, m, val, eps=10.0)
        elif kind == 1:
            ests[m].on_skip(now)
            stj = js.adapt_on_skip(stj, m, now, static, t_cp=t_cp)
        else:
            ests[m].on_sent()
            stj = js.adapt_on_sent(stj, m)
        for k in range(2):
            assert float(stj.current[k]) == \
                pytest.approx(ests[k].current, rel=1e-6)
            want_cs = ests[k]._cooling_start
            got_cs = float(stj.cooling_start[k])
            if want_cs is None:
                assert got_cs == -1.0
            else:
                assert got_cs == pytest.approx(want_cs)


def test_queue_push_pop_roundtrip():
    q = js.empty_edge_queue(4)
    q, ok = js.edge_push(q, 30.0, 0, 1.0, 30.0, 2)
    q, ok2 = js.edge_push(q, 10.0, 1, 1.0, 10.0, 1)
    assert bool(ok) and bool(ok2)
    q, idx, found = js.edge_pop_head(q)
    assert bool(found) and int(q.model[idx]) == 1   # earliest deadline first
    q, idx, found = js.edge_pop_head(q)
    assert bool(found) and int(q.model[idx]) == 2
    q, idx, found = js.edge_pop_head(q)
    assert not bool(found)


def test_queue_capacity_overflow_reports_failure():
    q = js.empty_edge_queue(2)
    for i in range(2):
        q, ok = js.edge_push(q, float(i), i, 1.0, 1.0, 0)
        assert bool(ok)
    q, ok = js.edge_push(q, 9.0, 9, 1.0, 1.0, 0)
    assert not bool(ok)
