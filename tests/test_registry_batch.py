"""One-program registry sweeps: padding exactness, 2-D sharding parity,
depth-aware pool wait, estimator telemetry, and signal-stacking guards.

1. the padded cross-scenario batch (``compile_registry_batch`` /
   ``run_batch``) reproduces the per-scenario ``run_fleet`` loop exactly
   for every registry scenario × policy × seed;
2. ``pad_signals`` masks replicas to the max shape with exact no-op
   padding; ``stack_signals`` raises a ValueError naming the mismatched
   field instead of an opaque stack error;
3. a 2-D (replica, edge) mesh-sharded ``run_batch`` is bitwise identical
   to the unsharded program (subprocess with forced host devices);
4. the depth-aware ``_pool_wait`` k-th order statistic: min-based in the
   empty-queue case, deeper slots under queueing, identically zero in the
   elastic limit;
5. ``record_trace`` carries the per-tick t̂ out of the scan on a
   ``FleetResult`` without disturbing the final state.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.task import PASSIVE, TABLE1
from repro.scenarios import (ScenarioSpec, ThetaTrapezium,
                             compile_fleet, compile_registry_batch,
                             fleet_summary, get, names, run_registry_sweep,
                             run_scenario_fleet)
from repro.sim.fleet_jax import (FleetPolicy, FleetResult, Profiles,
                                 _pool_wait, build_fleet_batch, init_state,
                                 pad_signals, run_batch, stack_signals)

MODELS = [TABLE1[n] for n in PASSIVE]
SWEEP_DURATION_MS = 10_000.0
SWEEP_POLICIES = ("DEMS-A", "GEMS-B-COOP")
SWEEP_SEEDS = (0, 1)
# the six policies this PR adds to the fleet backend (README matrix)
NEW_POLICIES = ("HPF", "CLD", "SJF-E+C", "SOTA1", "SOTA2", "GEMS-B")


# ---------------------------------------------------------------------------
# (1) padded one-program sweep ≡ per-scenario run_fleet loop, all scenarios
# ---------------------------------------------------------------------------

def test_registry_batch_matches_per_scenario_loop_exactly():
    """Padded path: a cooperative policy keeps multi-edge replicas."""
    rows = run_registry_sweep(None, SWEEP_POLICIES, SWEEP_SEEDS,
                              duration_ms=SWEEP_DURATION_MS)
    assert len(rows) == len(names()) * len(SWEEP_POLICIES) * len(SWEEP_SEEDS)
    for row in rows:
        spec = get(row["scenario"], duration_ms=SWEEP_DURATION_MS,
                   seed=row["seed"])
        want = fleet_summary(run_scenario_fleet(spec, row["policy"]))
        got = {k: row[k] for k in want}
        assert got == want, (row["scenario"], row["policy"], row["seed"])


def test_registry_sweep_runs_full_policy_matrix_in_one_program():
    """All six newly-covered policies (plus DEMS as the reference) sweep
    through ``run_registry_sweep`` — a *single* compiled program, policy
    flags being runtime ``PolicyParams`` — and each run's summary equals
    its standalone ``run_fleet`` loop exactly."""
    pols = NEW_POLICIES + ("DEMS",)
    rows = run_registry_sweep(("baseline", "cloud-crunch"), pols, (0,),
                              duration_ms=SWEEP_DURATION_MS)
    assert len(rows) == 2 * len(pols)
    for row in rows:
        spec = get(row["scenario"], duration_ms=SWEEP_DURATION_MS,
                   seed=row["seed"])
        want = fleet_summary(run_scenario_fleet(spec, row["policy"]))
        got = {k: row[k] for k in want}
        assert got == want, (row["scenario"], row["policy"])
    # the matrix really exercised distinct decision rules: cloud-only CLD
    # must differ from edge-only HPF on the same mission
    by = {(r["scenario"], r["policy"]): r for r in rows}
    assert by[("baseline", "CLD")]["qos_utility"] != \
        by[("baseline", "HPF")]["qos_utility"]


def test_registry_batch_edge_flattened_matches_loop_exactly():
    """Non-cooperative sweep: each (run, edge) becomes a 1-edge replica
    (zero edge padding) — per-run summaries still match the loop."""
    rows = run_registry_sweep(("rush-hour", "roaming-vips", "hetero-edges"),
                              ("DEMS", "EDF-E+C"), (0,),
                              duration_ms=SWEEP_DURATION_MS)
    for row in rows:
        spec = get(row["scenario"], duration_ms=SWEEP_DURATION_MS,
                   seed=row["seed"])
        want = fleet_summary(run_scenario_fleet(spec, row["policy"]))
        got = {k: row[k] for k in want}
        assert got == want, (row["scenario"], row["policy"])


def test_registry_batch_row_index_order_and_lanes():
    batch, rows = compile_registry_batch(("baseline", "rush-hour"),
                                         ("DEMS", "EDF-E+C"), (0, 1),
                                         duration_ms=5_000.0)
    assert [(r.scenario, r.policy, r.seed) for r in rows] == [
        ("baseline", "DEMS", 0), ("baseline", "DEMS", 1),
        ("baseline", "EDF-E+C", 0), ("baseline", "EDF-E+C", 1),
        ("rush-hour", "DEMS", 0), ("rush-hour", "DEMS", 1),
        ("rush-hour", "EDF-E+C", 0), ("rush-hour", "EDF-E+C", 1)]
    # non-coop sweep → edge-flattened: 4 baseline lanes + 8 rush-hour
    # lanes (2 edges each), disjoint and in order
    assert [r.lanes for r in rows[:4]] == [(0,), (1,), (2,), (3,)]
    assert [r.lanes for r in rows[4:]] == [(4, 5), (6, 7), (8, 9),
                                           (10, 11)]
    assert batch.signals.arrive.shape[0] == 12
    assert batch.signals.arrive.shape[2] == 1          # no edge padding
    # cooperative sweep → padded multi-edge replicas, one lane per run
    batch2, rows2 = compile_registry_batch(("baseline", "rush-hour"),
                                           ("DEMS-COOP",), (0,),
                                           duration_ms=5_000.0)
    assert [r.lanes for r in rows2] == [(0,), (1,)]
    assert batch2.signals.arrive.shape[:3] == (2, 200, 2)


# ---------------------------------------------------------------------------
# (2) pad_signals / stack_signals guards
# ---------------------------------------------------------------------------

def test_pad_signals_masks_to_max_shape():
    a = compile_fleet(get("baseline", duration_ms=5_000.0))       # 1 edge
    b = compile_fleet(get("roaming-vips", duration_ms=10_000.0))  # 3 edges
    sig = pad_signals([a, b])
    t, e, m = sig.arrive.shape[1:]
    assert (t, e, m) == (400, 3, 6)       # max ticks/edges/models
    valid = np.asarray(sig.valid)
    assert valid[0, :200, :1].all() and not valid[0, 200:].any() \
        and not valid[0, :, 1:].any()
    assert valid[1].all()
    # padded models never arrive; order stays a permutation everywhere
    assert not np.asarray(sig.arrive)[0, :, :, 4:].any()
    assert (np.sort(np.asarray(sig.order), axis=-1)
            == np.arange(m)).all()


def test_stack_signals_names_mismatched_field():
    a = compile_fleet(get("baseline", duration_ms=5_000.0))
    b = compile_fleet(get("rush-hour", duration_ms=5_000.0))  # 2 edges
    with pytest.raises(ValueError, match="field 'theta'"):
        stack_signals([a, b])
    with pytest.raises(ValueError, match="pad_signals"):
        stack_signals([a, b])


def test_build_fleet_batch_rejects_mixed_adapt_windows():
    sig = compile_fleet(get("baseline", duration_ms=5_000.0))
    runs = [(MODELS, FleetPolicy(adaptive=True, adapt_window=10), sig, 16),
            (MODELS, FleetPolicy(adaptive=True, adapt_window=5), sig, 16)]
    with pytest.raises(ValueError, match="adapt_window"):
        build_fleet_batch(runs)


# ---------------------------------------------------------------------------
# (3) 2-D (replica, edge) mesh sharding ≡ unsharded, bitwise
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import jax, numpy as np
    from repro.scenarios import compile_registry_batch
    from repro.sim.fleet_jax import run_batch
    batch, rows = compile_registry_batch(
        ("baseline", "rush-hour"), ("DEMS", "DEMS-COOP"), (0, 1),
        duration_ms=8_000.0)
    ref = run_batch(batch)
    mesh = jax.make_mesh((2, 2), ("replica", "edge"))
    got = run_batch(batch, mesh=mesh)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("SHARDING-PARITY-OK", len(rows), jax.device_count())
""")


def test_2d_sharded_run_batch_bitwise_matches_unsharded():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4 "
               + os.environ.get("XLA_FLAGS", ""),
               PYTHONPATH="src" + os.pathsep + os.environ.get(
                   "PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDING-PARITY-OK 8 4" in proc.stdout


# ---------------------------------------------------------------------------
# (4) depth-aware pool queue-wait (k-th order statistic)
# ---------------------------------------------------------------------------

def _state_with(busy, n_pending):
    prof = Profiles.build(MODELS)
    st = init_state(prof, cloud_slots=len(busy))
    cq_valid = st.cq.valid.at[:n_pending].set(True)
    return st._replace(cloud_busy_until=jnp.asarray(busy, jnp.float32),
                       cq=st.cq._replace(valid=cq_valid))


def test_pool_wait_empty_queue_reduces_to_min_based_estimate():
    st = _state_with([300.0, 100.0, 200.0], 0)
    assert float(_pool_wait(st, 40.0)) == 60.0      # min(busy) − now


def test_pool_wait_uses_queue_depth_order_statistic():
    st = _state_with([300.0, 100.0, 200.0], 2)      # 2 tasks ahead → k=2
    assert float(_pool_wait(st, 40.0)) == 260.0     # 3rd-soonest slot
    st = _state_with([300.0, 100.0, 200.0], 7)      # clamps at pool depth
    assert float(_pool_wait(st, 40.0)) == 260.0


def test_pool_wait_elastic_limit_identically_zero():
    st = _state_with([0.0] * 8, 5)                  # ample free pool
    assert float(_pool_wait(st, 123.0)) == 0.0


def test_pool_wait_ignores_steal_only_parkees():
    st = _state_with([300.0, 100.0, 200.0], 2)
    st = st._replace(cq=st.cq._replace(
        steal_only=st.cq.steal_only.at[:2].set(True)))
    assert float(_pool_wait(st, 40.0)) == 60.0      # back to k=0


# ---------------------------------------------------------------------------
# (5) estimator telemetry: per-tick t̂ trace on FleetResult
# ---------------------------------------------------------------------------

def _trace_spec():
    return ScenarioSpec(
        name="trace-test", duration_ms=60_000.0,
        theta=ThetaTrapezium(ramp_up=(5_000.0, 15_000.0),
                             ramp_down=(45_000.0, 55_000.0)))


def test_record_trace_returns_fleet_result_with_t_hat():
    spec = _trace_spec()
    res = run_scenario_fleet(spec, "DEMS-A", record_trace=True)
    assert isinstance(res, FleetResult)
    n_ticks = int(spec.duration_ms / 25.0)
    assert res.t_hat.shape == (n_ticks, spec.n_edges, len(spec.models))
    static = np.asarray([m.t_cloud for m in spec.models])
    t_hat = np.asarray(res.t_hat)
    assert (t_hat[0] == static).all()               # starts at Table-1 t̂
    assert (t_hat.max(axis=(1, 2)) > static.max() + 1.0).any()  # reacted


def test_record_trace_leaves_final_state_untouched():
    spec = _trace_spec()
    res = run_scenario_fleet(spec, "DEMS-A", record_trace=True)
    plain = run_scenario_fleet(spec, "DEMS-A")
    for a, b in zip(jax.tree.leaves(res.final), jax.tree.leaves(plain)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
