"""Flight recorder: bit-identity, conservation, tails, exports, retrace guard.

1. tracing is *free of observable effect*: trace-on final states are
   bitwise identical to trace-off for ``run_fleet`` and the one-program
   registry sweep (the trace-off program is literally the pre-recorder
   executable — ``TraceSpec`` is part of the program cache key);
2. the per-tick conservation ledger ``arrived = settled + in-flight``
   holds on every tick of every registry scenario × {DEMS-A, GEMS-COOP,
   SOTA1}, and counter totals equal the end-of-run summary stats;
3. padded batch cells record nothing: events are zeroed where
   ``valid=False`` while gauges hold the final depths, so the ledger
   stays exact through a padded tail;
4. histogram percentiles: totals survive clamping/overflow, known
   distributions give known p50/p95/p99, empty gives nan;
5. exports (JSON/CSV/Perfetto) parse and carry the series;
6. the deprecated ``record_trace`` alias ≡ ``TraceSpec(t_hat=True)``;
7. the serve engine's ``metrics_snapshot`` endpoint;
8. the retrace guard: a multi-policy sweep jit-traces each cached tick
   program exactly once (``compile_guard`` fixture).
"""
import json

import jax
import numpy as np
import pytest

from repro.core.schedulers import make_policy
from repro.core.task import ModelProfile
from repro.obs import TraceSpec, metrics
from repro.obs.trace import hist_counts, resolve_spec
from repro.scenarios import (get, names, run_registry_sweep,
                             run_scenario_fleet)
from repro.serve.engine import ServableModel, ServeEngine, run_stream
from repro.sim.fleet_jax import build_fleet_batch, pad_signals, run_batch

D = 8_000.0
TSPEC = TraceSpec.full()


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# (1) tracing never changes results — bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["DEMS-A", "GEMS-COOP"])
def test_trace_on_bitwise_identical_run_fleet(policy):
    spec = get("rush-hour", duration_ms=D)
    plain = run_scenario_fleet(spec, policy)
    traced = run_scenario_fleet(spec, policy, trace=TSPEC)
    _assert_trees_equal(plain, traced.final)


def test_trace_on_bitwise_identical_fleet_batch():
    from repro.scenarios import run_scenario_fleet_batch

    spec = get("baseline", duration_ms=5_000.0)
    plain = run_scenario_fleet_batch(spec, "DEMS-A", (0, 1))
    traced = run_scenario_fleet_batch(spec, "DEMS-A", (0, 1), trace=TSPEC)
    _assert_trees_equal(plain, traced.final)
    assert traced.t_hat.ndim == 4 and traced.t_hat.shape[0] == 2  # [R,T,E,M]
    for r in range(2):
        metrics.check_conservation(
            metrics.select_replica(traced.counters, r))


def test_trace_on_bitwise_identical_registry_sweep():
    kw = dict(scenarios=("baseline", "cloud-crunch"),
              policies=("DEMS-A", "SOTA1"), seeds=(0,), duration_ms=D)
    plain = run_registry_sweep(**kw)
    traced = run_registry_sweep(**kw, trace=TSPEC)
    for p, t in zip(plain, traced):
        assert p == {k: t[k] for k in p}
        assert t["trace"].counters is not None


# ---------------------------------------------------------------------------
# (2) conservation + counters ≡ summaries, all scenarios × 3 policies
# ---------------------------------------------------------------------------

def test_conservation_and_summary_match_across_registry():
    rows = run_registry_sweep(None, ("DEMS-A", "GEMS-COOP", "SOTA1"),
                              (0,), duration_ms=D, trace=TSPEC)
    assert len(rows) == len(names()) * 3
    for row in rows:
        c = row["trace"].counters
        metrics.check_conservation(c)
        # per-model outcome deltas sum to exactly the run's summary
        assert int(np.asarray(c.hit).sum()) == row["completed"]
        assert int(np.asarray(c.miss).sum()) == row["missed"]
        assert int(np.asarray(c.drop).sum()) == row["dropped"]
        assert int(np.asarray(c.stolen).sum()) == row["stolen"]
        np.testing.assert_allclose(float(np.asarray(c.qos).sum()),
                                   row["qos_utility"], rtol=1e-5)
        # per-task tail evidence covers every deadline hit
        assert int(np.asarray(c.slack_hist).sum()) == row["completed"]
        assert int(np.asarray(c.latency_hist).sum()) == row["completed"]
        # every drop has a cause
        by_cause = (np.asarray(c.drop_infeasible).sum()
                    + np.asarray(c.drop_unstolen).sum()
                    + np.asarray(c.drop_qfull).sum())
        assert int(by_cause) == row["dropped"]


# ---------------------------------------------------------------------------
# (3) padded cells record nothing
# ---------------------------------------------------------------------------

def test_padded_tail_masks_events_and_holds_gauges():
    from repro.scenarios import compile_fleet

    short = get("baseline", duration_ms=5_000.0)      # 200 ticks, 1 edge
    long = get("roaming-vips", duration_ms=10_000.0)  # 400 ticks, 3 edges
    sig = pad_signals([compile_fleet(short), compile_fleet(long)])
    runs = [(short.models, "DEMS-A", jax.tree.map(lambda a: a[0], sig),
             short.cloud_concurrency),
            (long.models, "DEMS-A", jax.tree.map(lambda a: a[1], sig),
             long.cloud_concurrency)]
    batch = build_fleet_batch(runs)
    res = run_batch(batch, trace=TSPEC)
    c = metrics.select_replica(res.counters, 0)
    valid = np.asarray(c.valid)                        # [T, E]
    assert valid[:200, 0].all() and not valid[200:].any() \
        and not valid[:, 1:].any()
    dead = ~valid
    for f in ("arrivals", "admit_edge", "admit_cloud", "cloud_dispatch",
              "edge_exec", "peer_out", "peer_in", "drop_infeasible"):
        assert not np.asarray(getattr(c, f))[dead].any(), f
    # outcome deltas are state deltas: reverted state ⇒ zero in the tail
    assert not np.asarray(c.hit)[dead].any()
    # gauges hold through the tail, keeping the ledger exact
    metrics.check_conservation(c)
    metrics.check_conservation(metrics.select_replica(res.counters, 1))


# ---------------------------------------------------------------------------
# (4) histograms and percentiles
# ---------------------------------------------------------------------------

def test_hist_counts_preserves_totals_under_clamp_and_overflow():
    spec = TraceSpec(counters=True, hist_bins=8, hist_max_ms=800.0)
    vals = np.array([-50.0, 0.0, 99.0, 100.0, 799.0, 800.0, 5_000.0])
    mask = np.ones(len(vals), bool)
    h = np.asarray(hist_counts(vals, mask, spec))
    assert h.sum() == len(vals)
    assert h[0] == 2 + 1          # clamp: -50 and 0, plus 99
    assert h[-1] == 3             # 799 in-range + 800, 5000 overflow
    assert np.asarray(hist_counts(vals, np.zeros(len(vals), bool),
                                  spec)).sum() == 0


def test_hist_percentiles_known_distribution():
    spec = TraceSpec(counters=True, hist_bins=4, hist_max_ms=400.0)
    p = metrics.hist_percentiles(np.array([1, 1, 1, 1]), spec)
    assert p["p50"] == pytest.approx(200.0)
    assert p["p99"] == pytest.approx(396.0)
    empty = metrics.hist_percentiles(np.zeros(4), spec)
    assert all(np.isnan(v) for v in empty.values())
    # stacked per-tick histograms reduce before the percentile
    stacked = np.tile(np.array([0, 4, 0, 0]), (7, 3, 1))
    assert metrics.hist_percentiles(stacked, spec)["p50"] == \
        pytest.approx(150.0)


# ---------------------------------------------------------------------------
# (5) exports
# ---------------------------------------------------------------------------

def test_exports_parse_and_carry_series():
    spec = get("cloud-crunch", duration_ms=D)
    res = run_scenario_fleet(spec, "DEMS-A", trace=TSPEC)
    doc = json.loads(metrics.to_json(res.counters, TSPEC,
                                     list(spec.model_names)))
    n_ticks = len(doc["series"]["arrivals"])
    assert n_ticks == np.asarray(res.counters.valid).shape[0]
    assert doc["ledger"]["residual"] == [0] * n_ticks
    assert set(doc["tail"]["qoe_frequency"]) == set(spec.model_names)

    csv_text = metrics.to_csv(res.counters)
    assert len(csv_text.strip().splitlines()) == n_ticks + 1

    pf = json.loads(metrics.to_perfetto(res.counters, dt_ms=25.0,
                                        stride=10))
    counter_events = [e for e in pf["traceEvents"] if e.get("ph") == "C"]
    assert counter_events and all("args" in e for e in counter_events)


# ---------------------------------------------------------------------------
# (6) deprecated alias
# ---------------------------------------------------------------------------

def test_record_trace_alias_matches_tracespec():
    spec = get("baseline", duration_ms=D)
    old = run_scenario_fleet(spec, "DEMS-A", record_trace=True)
    new = run_scenario_fleet(spec, "DEMS-A",
                             trace=TraceSpec(t_hat=True))
    np.testing.assert_array_equal(np.asarray(old.t_hat),
                                  np.asarray(new.t_hat))
    assert old.counters is None and new.counters is None
    assert resolve_spec(None, True) == TraceSpec(t_hat=True)
    with pytest.raises(TypeError, match="TraceSpec"):
        resolve_spec(True)


# ---------------------------------------------------------------------------
# (7) serve engine snapshot endpoint
# ---------------------------------------------------------------------------

def test_serve_metrics_snapshot():
    prof = ModelProfile(name="HV", beta=100, deadline=400.0, t_edge=5.0,
                        t_cloud=60.0, cost_edge=1, cost_cloud=25)
    models = {"HV": ServableModel(profile=prof, run=lambda: None)}
    engine = ServeEngine(make_policy("DEMS"), models,
                         cloud_concurrency=2, seed=0)
    run_stream(engine, {"HV": 20.0}, duration_ms=1_000.0)
    snap = engine.metrics_snapshot()
    assert snap["policy"] == "DEMS"
    assert snap["hit"] > 0
    settled = snap["hit"] + snap["miss"] + snap["dropped"]
    assert settled <= snap["per_model"]["HV"]["generated"]
    assert snap["hit_rate"] == pytest.approx(snap["hit"] / settled)
    assert snap["latency_ms"]["p50"] is not None
    assert snap["slack_ms"]["p99"] is not None
    assert snap["window"]["latency_samples"] == snap["hit"]
    freq = snap["per_model"]["HV"]["qoe_frequency"]
    assert freq == pytest.approx(snap["hit"] / settled)


# ---------------------------------------------------------------------------
# (8) retrace guard: policies stay runtime data
# ---------------------------------------------------------------------------

def test_multi_policy_sweep_traces_each_program_once(compile_guard):
    spec = get("rush-hour", duration_ms=5_000.0)
    run_scenario_fleet(spec, "DEMS-A", trace=TSPEC)  # shape-driven trace
    compile_guard.arm()
    for pol in ("GEMS-B", "SOTA1", "EDF-E+C"):       # policies are runtime
        run_scenario_fleet(spec, pol, trace=TSPEC)   # data: no new traces
    # compile_guard teardown asserts the trace count never grew
