"""Stochastic execution durations: the sampled ``exec_jit`` lane, the
same-sample table-backed oracle, the lockstep multi-edge ``FleetOracle``,
seeded determinism across every entry point, and the tick-rounding
regression guard."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.schedulers import make_policy
from repro.core.task import PASSIVE, TABLE1
from repro.scenarios import (DurationJitter, compile_exec_jitter,
                             fleet_summary, fleet_summary_batch, get,
                             run_registry_sweep, run_scenario_fleet,
                             run_scenario_fleet_batch, run_scenario_oracle)
from repro.scenarios.compile import compile_fleet, n_steps
from repro.sim.engine import Arrival, FleetOracle, Simulator
from repro.sim.network import EdgeLatencyModel

MODELS = [TABLE1[n] for n in PASSIVE]


# ---------------------------------------------------------------------------
# tick rounding (regression: int() truncation silently dropped ticks)
# ---------------------------------------------------------------------------

def test_n_steps_rounds_float_noise_and_rejects_non_divisible():
    assert n_steps(300_000.0, 25.0) == 12_000
    # 3 * 0.1 = 0.30000000000000004: int() truncation would give 2
    assert n_steps(0.1 + 0.1 + 0.1, 0.1) == 3
    with pytest.raises(ValueError, match="not an integer multiple"):
        n_steps(1_000.0, 300.0)
    with pytest.raises(ValueError):
        n_steps(10.0, 300.0)          # would round to zero ticks


def test_compile_fleet_rejects_non_divisible_duration():
    spec = get("baseline", duration_ms=1_010.0)
    with pytest.raises(ValueError, match="not an integer multiple"):
        compile_fleet(spec)


# ---------------------------------------------------------------------------
# the sampled jitter tables
# ---------------------------------------------------------------------------

def test_exec_jitter_tables_seeded_clipped_and_unit_median():
    spec = get("duration-jitter", duration_ms=30_000.0)
    ej, cj = compile_exec_jitter(spec)
    m = len(spec.model_names)
    assert ej.shape == (1_200, m) and cj.shape == (1_200, m)
    j = spec.jitter
    assert ej.min() >= j.edge_clip[0] and ej.max() <= j.edge_clip[1]
    assert cj.min() >= j.cloud_clip[0] and cj.max() <= j.cloud_clip[1]
    # log-normal with zero log-mean: the sample log-mean sits near 0
    assert abs(np.log(ej).mean()) < 0.02
    # same spec, same tables; different mission seed, different tables
    ej2, cj2 = compile_exec_jitter(spec)
    np.testing.assert_array_equal(ej, ej2)
    np.testing.assert_array_equal(cj, cj2)
    ej3, _ = compile_exec_jitter(dataclasses.replace(spec, seed=1))
    assert not np.array_equal(ej, ej3)


def test_heavy_tail_inflates_cloud_samples_only():
    spec = get("heavy-tail", duration_ms=60_000.0)
    ej, cj = compile_exec_jitter(spec)
    # ~5 % of cloud samples are tripled: far beyond the 0.25-σ body
    assert (cj > 2.0).mean() > 0.01
    assert cj.max() <= spec.jitter.cloud_clip[1]
    assert ej.max() <= spec.jitter.edge_clip[1] < 2.0


def test_jitter_none_gives_unit_tables():
    ej, cj = compile_exec_jitter(get("baseline", duration_ms=10_000.0))
    assert (ej == 1.0).all() and (cj == 1.0).all()


# ---------------------------------------------------------------------------
# zero-variance mode ≡ today's deterministic goldens, bit for bit
# ---------------------------------------------------------------------------

def test_zero_variance_jitter_is_bitwise_deterministic_run():
    spec = get("rush-hour", duration_ms=30_000.0)
    frozen = dataclasses.replace(spec, jitter=DurationJitter(
        edge_sigma=0.0, cloud_sigma=0.0, heavy_tail_p=0.0))
    a = run_scenario_fleet(spec, "DEMS-A")
    b = run_scenario_fleet(frozen, "DEMS-A")
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# fixed-seed determinism across every entry point
# ---------------------------------------------------------------------------

def test_fixed_seed_determinism_across_entry_points():
    spec = get("heavy-tail", duration_ms=15_000.0)
    once = fleet_summary(run_scenario_fleet(spec, "DEMS-A"))
    again = fleet_summary(run_scenario_fleet(spec, "DEMS-A"))
    assert once == again
    batch = fleet_summary_batch(
        run_scenario_fleet_batch(spec, "DEMS-A", seeds=(0,)))[0]
    assert batch == once
    row = run_registry_sweep(["heavy-tail"], ("DEMS-A",), (0,),
                             duration_ms=15_000.0)[0]
    for k in ("completed", "missed", "dropped", "qos_utility",
              "qoe_utility"):
        assert row[k] == once[k], (k, row[k], once[k])


# ---------------------------------------------------------------------------
# fleet vs the same-sample oracle on the stochastic scenarios
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario,policy", [
    ("duration-jitter", "DEMS-A"),
    ("duration-jitter", "GEMS"),
    ("duration-jitter", "DEMS-COOP"),
    ("heavy-tail", "DEMS-A"),
    ("heavy-tail", "GEMS"),
])
def test_fleet_matches_oracle_on_stochastic_scenarios(scenario, policy):
    """With ``spec.jitter`` set, the oracle's table-backed latency models
    replay the *same* per-(tick, model) samples the fleet's ``exec_jit``
    lane consumes, so agreement stays <10 % even though durations are
    stochastic; ``*-COOP`` runs through the lockstep multi-edge
    :class:`FleetOracle`."""
    spec = get(scenario, duration_ms=60_000.0)
    oracle = run_scenario_oracle(spec, policy).merged
    fleet = fleet_summary(run_scenario_fleet(spec, policy))
    d_done = abs(fleet["completed"] - oracle.completed) / oracle.completed
    d_qos = abs(fleet["qos_utility"] - oracle.qos_utility) / \
        abs(oracle.qos_utility)
    assert d_done < 0.10, (policy, fleet["completed"], oracle.completed)
    assert d_qos < 0.10, (policy, fleet["qos_utility"], oracle.qos_utility)


# ---------------------------------------------------------------------------
# the lockstep multi-edge oracle
# ---------------------------------------------------------------------------

def test_coop_oracle_single_edge_reduces_to_silo():
    """One edge (or ``max_transfers=0``) leaves nothing to exchange: the
    sliced lockstep run must settle every task exactly like the plain
    independent-simulator path."""
    spec = get("heavy-tail", duration_ms=30_000.0)
    coop = run_scenario_oracle(spec, "DEMS-COOP").merged
    silo = run_scenario_oracle(spec, "DEMS").merged
    assert coop.completed == silo.completed
    assert coop.qos_utility == pytest.approx(silo.qos_utility)


def test_fleet_oracle_moves_tasks_off_the_overloaded_edge():
    """Edge 0 drowning, edge 1 idle: with a positive slack threshold the
    exchange round must export tight-slack tasks to the idle edge (DEMS's
    feasibility-checked inserts keep *projected* slack non-negative, so
    ``slack_ms=0`` would never fire here), and every task — moved or not
    — still reaches a terminal state (conservation)."""
    em = EdgeLatencyModel(mean_frac=0.62, sd_frac=0.0, lo_frac=0.62,
                          hi_frac=0.62)
    flood = [Arrival(time=float(i * 5), model=MODELS[i % len(MODELS)],
                     drone=0) for i in range(120)]
    idle = [Arrival(time=10_000.0, model=MODELS[0], drone=1)]
    sims = [Simulator(make_policy("DEMS"), arr, 30_000.0, seed=e,
                      edge_model=em)
            for e, arr in enumerate((flood, idle))]
    orc = FleetOracle(sims, 30_000.0, dt=25.0, slack_ms=400.0,
                      max_transfers=2)
    results = orc.run()
    assert orc.peer_moved > 0
    generated = sum(st.generated for r in results
                    for st in r.per_model.values())
    settled = sum(st.edge_success + st.edge_miss + st.cloud_success
                  + st.cloud_miss + st.dropped
                  for r in results for st in r.per_model.values())
    assert generated == len(flood) + len(idle)
    assert settled == generated
