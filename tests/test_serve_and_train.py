"""Integration tests: live serve engine + end-to-end training loop."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.schedulers import make_policy
from repro.core.task import ModelProfile
from repro.serve.engine import ServableModel, ServeEngine, run_stream
from repro.train.loop import train
from repro.train import checkpoint as ckpt


def _servable(name, arch, beta=100, ke=1, kc=25, deadline=400.0):
    cfg = reduced(ARCHS[arch], n_layers=2, d_model=128, vocab=512)
    prof = ModelProfile(name=name, beta=beta, deadline=deadline,
                        t_edge=20.0, t_cloud=60.0, cost_edge=ke,
                        cost_cloud=kc, qoe_beta=50.0, qoe_alpha=0.8,
                        qoe_window=2_000.0)
    return ServableModel.from_arch(prof, cfg, batch=1, seq=16)


def test_serve_engine_runs_real_models():
    models = {"HV": _servable("HV", "granite-3-2b"),
              "BP": _servable("BP", "starcoder2-3b", beta=40, kc=43)}
    engine = ServeEngine(make_policy("DEMS"), models, cloud_concurrency=2,
                         seed=0)
    r = run_stream(engine, {"HV": 12.0, "BP": 6.0}, duration_ms=3_000.0)
    assert r.generated >= 40
    assert r.completed > 0
    assert r.completion_rate > 0.5
    # conservation
    for st in r.per_model.values():
        done = (st.edge_success + st.edge_miss + st.cloud_success
                + st.cloud_miss + st.dropped)
        assert done <= st.generated    # a few may be in flight at stop


def test_serve_engine_gems_windows():
    models = {"HV": _servable("HV", "granite-3-2b")}
    engine = ServeEngine(make_policy("GEMS"), models, cloud_concurrency=2,
                         seed=0)
    r = run_stream(engine, {"HV": 15.0}, duration_ms=3_000.0)
    st = r.per_model["HV"]
    assert st.windows_total >= 1
    assert st.qoe_utility == st.windows_met * 50.0


def test_train_loop_learns_and_checkpoints(tmp_path):
    cfg = reduced(ARCHS["granite-3-2b"], n_layers=2, d_model=128, vocab=256)
    path = str(tmp_path / "ck")
    state, losses = train(cfg, steps=60, batch=8, seq_len=64,
                          checkpoint_path=path, log=lambda *a: None)
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1, \
        "loss did not decrease"
    restored = ckpt.load(path, state.params)
    for a, b in zip(jax.tree.leaves(restored),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    import jax.numpy as jnp
    path = str(tmp_path / "ck2")
    ckpt.save(path, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        ckpt.load(path, {"w": jnp.zeros((5, 4))})


def test_data_pipeline_determinism_and_structure():
    from repro.data.pipeline import FastSyntheticLM
    a = next(FastSyntheticLM(vocab=128, seq_len=32, batch=4).batches())
    b = next(FastSyntheticLM(vocab=128, seq_len=32, batch=4).batches())
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    assert a["tokens"].shape == (4, 32)
    assert (a["tokens"] < 128).all()
    # structure exists: derived tokens appear at the advertised rate
    # (mixing cascades, so only pairs whose source token survived match)
    derived = (a["labels"] == (a["tokens"] * 31 + 7) % 128).mean()
    assert derived > 0.2
