"""Smoke tests for the CI benchmark gates — the *logic*, not the measuring.

``bench_fleet.check`` and ``bench_serve.check_gate`` are the exit-code
guards CI runs against the committed ``BENCH_fleet.json`` baseline.
These tests feed them synthetic reports (an injected >25 % slowdown, a
planner parity mismatch, a backpressure leak, …) and assert each gate
trips — so a regression in the gate itself cannot silently wave a real
regression through.

The benchmark modules live outside the package (``benchmarks/``); they
are loaded by file path.  ``bench_fleet`` prepends
``--xla_force_host_platform_device_count`` to ``XLA_FLAGS`` at import —
the loader restores the environment so the test process's device
topology is untouched.
"""
import importlib.util
import json
import os
import pathlib

import pytest

pytestmark = pytest.mark.bench_gate

_BENCH_DIR = pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
_MODULES: dict = {}


def _load(name):
    if name in _MODULES:
        return _MODULES[name]
    old = os.environ.get("XLA_FLAGS")
    try:
        spec = importlib.util.spec_from_file_location(
            name, _BENCH_DIR / f"{name}.py")
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
    finally:
        if old is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = old
    _MODULES[name] = mod
    return mod


# ---------------------------------------------------------------- fleet


def _fleet_baseline(tmp_path, ticks_per_sec=1_000.0):
    p = tmp_path / "BENCH_fleet.json"
    p.write_text(json.dumps(
        {"quick": {"throughput": {"ticks_per_sec": ticks_per_sec}}}))
    return p


def test_fleet_gate_trips_on_synthetic_slowdown(tmp_path, capsys):
    bf = _load("bench_fleet")
    base = _fleet_baseline(tmp_path)
    # 30 % slower than baseline at the default 25 % tolerance
    report = dict(quick=True, throughput=dict(ticks_per_sec=700.0))
    assert bf.check(report, base, 0.25) == 1
    assert "regressed" in capsys.readouterr().out


def test_fleet_gate_passes_within_tolerance(tmp_path):
    bf = _load("bench_fleet")
    base = _fleet_baseline(tmp_path)
    report = dict(quick=True, throughput=dict(ticks_per_sec=800.0))
    assert bf.check(report, base, 0.25) == 0


def test_fleet_gate_missing_mode_section(tmp_path):
    bf = _load("bench_fleet")
    base = tmp_path / "BENCH_fleet.json"
    base.write_text(json.dumps({"full": {}}))
    assert bf.check(dict(quick=True), base, 0.25) == 1


def test_fleet_gate_sweep_mismatch(tmp_path, capsys):
    bf = _load("bench_fleet")
    base = _fleet_baseline(tmp_path)
    report = dict(quick=True,
                  sweep=dict(loop_vs_batch_mismatches=2))
    assert bf.check(report, base, 0.25) == 1
    assert "diverge" in capsys.readouterr().out


def test_fleet_gate_policy_retrace(tmp_path):
    bf = _load("bench_fleet")
    base = _fleet_baseline(tmp_path)
    report = dict(quick=True, trace=dict(
        overhead_frac=0.05, ticks_per_sec_on=900.0,
        ticks_per_sec_off=950.0, policy_generic=False))
    assert bf.check(report, base, 0.25) == 1


def _scaling(mismatches=0, parity=True, speedup=1.5):
    return dict(donation_parity_ok=parity,
                sweep=dict(mismatches=mismatches,
                           speedup_vs_padded=speedup))


def test_fleet_gate_scaling_bucket_mismatch(tmp_path, capsys):
    bf = _load("bench_fleet")
    base = _fleet_baseline(tmp_path)
    report = dict(quick=True, scaling=_scaling(mismatches=1))
    assert bf.check(report, base, 0.25) == 1
    assert "padded reference" in capsys.readouterr().out


def test_fleet_gate_scaling_donation_parity(tmp_path, capsys):
    bf = _load("bench_fleet")
    base = _fleet_baseline(tmp_path)
    report = dict(quick=True, scaling=_scaling(parity=False))
    assert bf.check(report, base, 0.25) == 1
    assert "donated" in capsys.readouterr().out


def test_fleet_gate_full_report_passes(tmp_path, capsys):
    bf = _load("bench_fleet")
    base = _fleet_baseline(tmp_path)
    report = dict(
        quick=True,
        throughput=dict(ticks_per_sec=1_100.0),
        sweep=dict(loop_vs_batch_mismatches=0),
        trace=dict(overhead_frac=0.05, ticks_per_sec_on=900.0,
                   ticks_per_sec_off=950.0, policy_generic=True),
        scaling=_scaling())
    assert bf.check(report, base, 0.25) == 0
    assert "OK" in capsys.readouterr().out


# ---------------------------------------------------------------- serve


def _serve_section(**over):
    s = dict(
        per_tick_ms={"p50": 1.0, "p95": 2.0, "p99": 3.0},
        backpressure=dict(max_pending_ticks=64, submitted=5_000,
                          accepted=128, shed=4_872, pending_ticks=64))
    s.update(over)
    return s


def _serve_baseline(tmp_path, p95=1.5):
    p = tmp_path / "BENCH_fleet.json"
    p.write_text(json.dumps(
        {"quick": {"controller": {"per_tick_ms": {"p95": p95}}}}))
    return p


def test_serve_gate_trips_on_p95_regression(tmp_path, capsys):
    bs = _load("bench_serve")
    base = _serve_baseline(tmp_path, p95=0.9)   # 2.0 / 0.9 > 2x
    assert bs.check_gate(_serve_section(), base, "quick") == 1
    assert "regressed" in capsys.readouterr().out


def test_serve_gate_passes_within_bound(tmp_path):
    bs = _load("bench_serve")
    base = _serve_baseline(tmp_path, p95=1.5)   # 2.0 / 1.5 < 2x
    assert bs.check_gate(_serve_section(), base, "quick") == 0


def test_serve_gate_skips_missing_baseline(tmp_path, capsys):
    bs = _load("bench_serve")
    base = tmp_path / "BENCH_fleet.json"
    base.write_text(json.dumps({"full": {}}))
    assert bs.check_gate(_serve_section(), base, "quick") == 0
    assert "skipped" in capsys.readouterr().out


@pytest.mark.parametrize("bp_over", [
    dict(shed=0, accepted=5_000),          # nothing shed: unbounded buffer
    dict(accepted=100),                    # accepted + shed != submitted
    dict(pending_ticks=65),                # pending grew past the bound
])
def test_serve_gate_backpressure_invariants(tmp_path, bp_over, capsys):
    bs = _load("bench_serve")
    base = _serve_baseline(tmp_path)
    section = _serve_section()
    section["backpressure"] = {**section["backpressure"], **bp_over}
    assert bs.check_gate(section, base, "quick") == 1
    assert "backpressure" in capsys.readouterr().out
