"""Compile-cache discipline of the shape-bucketed sweep planner.

Two properties keep metropolis-scale sweeps from drowning in XLA:

* **bucket → trace accounting** — a sweep over N distinct shape buckets
  pays exactly N jit traces (the tick program is policy-generic and
  shape-keyed, nothing else), and re-running the identical sweep pays
  zero;
* **bounded program cache** — ``_fleet_program`` is an LRU with capacity
  ``FLEET_PROGRAM_CACHE_CAPACITY``; a long-lived process churning
  through ad-hoc statics evicts instead of growing without bound, and
  the eviction count is observable via ``fleet_compile_stats``.
"""
import pytest

from repro.obs import prof
from repro.obs.trace import TraceSpec
from repro.sim import fleet_jax


@pytest.fixture(autouse=True)
def _fresh_programs():
    """Count traces from zero and leave no fuzz-sized programs behind."""
    prof.reset_fleet_programs()
    yield
    prof.reset_fleet_programs()


def test_three_bucket_sweep_compiles_three_programs(compile_guard):
    from repro.scenarios import run_registry_sweep

    # baseline (1 edge, PASSIVE), rush-hour (2 edges, PASSIVE) and
    # roaming-vips (3 edges, ACTIVE) land in three distinct coop
    # buckets under GEMS-COOP — three exact shapes, three traces
    scenarios = ("baseline", "rush-hour", "roaming-vips")
    rows = run_registry_sweep(scenarios, ("GEMS-COOP",), (0,),
                              duration_ms=4_000.0, planner="bucketed")
    assert [r["scenario"] for r in rows] == list(scenarios)
    stats = prof.fleet_compile_stats()
    assert stats.traces == 3, (
        f"3-bucket sweep should trace exactly 3 programs, "
        f"got {stats.traces}")

    # the identical sweep again: every bucket hits the jit cache
    compile_guard.arm()
    rerun = run_registry_sweep(scenarios, ("GEMS-COOP",), (0,),
                               duration_ms=4_000.0, planner="bucketed")
    assert rerun == rows
    # compile_guard teardown asserts the rerun traced 0 new programs


def test_program_cache_evicts_beyond_capacity(monkeypatch):
    monkeypatch.setattr(fleet_jax, "FLEET_PROGRAM_CACHE_CAPACITY", 2)
    # building a program is cheap (the jit wrapper traces lazily), so
    # churning statics through a capacity-2 cache must evict the LRU
    # entry instead of growing without bound
    progs = [fleet_jax._fleet_program(dt, 0.62, 0.80, 0, TraceSpec(),
                                      False, False, False)
             for dt in (11.0, 13.0, 17.0)]
    stats = prof.fleet_compile_stats()
    assert stats.capacity == 2
    assert stats.programs <= 2
    assert stats.evictions >= 1
    # the newest entry survived and is returned by identity on re-request
    assert fleet_jax._fleet_program(17.0, 0.62, 0.80, 0, TraceSpec(),
                                    False, False, False) is progs[-1]
    # 11.0 was the LRU casualty: re-requesting it builds a fresh program
    assert fleet_jax._fleet_program(11.0, 0.62, 0.80, 0, TraceSpec(),
                                    False, False, False) is not progs[0]


def test_cache_clear_resets_registry_and_evictions():
    fleet_jax._fleet_program(19.0, 0.62, 0.80, 0, TraceSpec(),
                             False, False, False)
    assert prof.fleet_compile_stats().programs == 1
    prof.reset_fleet_programs()
    stats = prof.fleet_compile_stats()
    assert stats.programs == 0
    assert stats.traces == 0
    assert stats.evictions == 0
