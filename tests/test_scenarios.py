"""Scenario engine acceptance tests (ISSUE 1).

1. a single-edge, no-event scenario compiles to the existing
   ``task_stream`` workload bit-for-bit;
2. on a 2-edge fleet with one overloaded edge, cross-edge peer offload
   strictly increases completed tasks over cooperation disabled;
3. a handover scenario re-homes a roaming drone's arrivals to the
   covering edge in both the oracle and the JAX fleet sim.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.task import PASSIVE, TABLE1
from repro.scenarios import (Burst, CloudOutage, DroneSpec, EdgeSite,
                             ScenarioSpec, compile_fleet, compile_oracle,
                             fleet_summary, get, names, run_scenario_fleet,
                             run_scenario_oracle)
from repro.sim.fleet_jax import FleetPolicy, run_fleet
from repro.sim.workloads import task_stream

MODELS = [TABLE1[n] for n in PASSIVE]


# ---------------------------------------------------------------------------
# (1) degenerate scenario ≡ existing workload
# ---------------------------------------------------------------------------

def test_baseline_scenario_reproduces_task_stream_bit_for_bit():
    spec = get("baseline", duration_ms=60_000.0, seed=3)
    compiled = compile_oracle(spec)
    want = task_stream(MODELS, n_drones=3, duration_ms=60_000.0, seed=3)
    assert compiled.edge_arrivals == [want]


# ---------------------------------------------------------------------------
# (2) peer offload rescues an overloaded edge
# ---------------------------------------------------------------------------

def test_peer_offload_strictly_increases_completed_tasks():
    # all six drones camp on edge 0; edge 1 idles nearby
    spec = ScenarioSpec(
        name="hotspot", duration_ms=60_000.0,
        edges=(EdgeSite(0, 0), EdgeSite(3_000, 0)),
        drones=tuple(DroneSpec(waypoints=((10.0 * i, 0.0),))
                     for i in range(6)))
    signals = compile_fleet(spec)
    coop = run_fleet(spec.models, FleetPolicy(cooperation=True), signals)
    silo = run_fleet(spec.models, FleetPolicy(), signals)
    n_coop = int(np.asarray(coop.n_success).sum())
    n_silo = int(np.asarray(silo.n_success).sum())
    assert int(np.asarray(coop.n_peer_out).sum()) > 0
    assert np.asarray(coop.n_peer_out)[0] > 0          # exporter is edge 0
    assert np.asarray(coop.n_peer_in)[1] > 0           # importer is edge 1
    assert n_coop > n_silo


def test_peer_offload_noop_on_single_edge():
    spec = get("baseline", duration_ms=30_000.0)
    signals = compile_fleet(spec)
    coop = run_fleet(spec.models, FleetPolicy(cooperation=True), signals)
    silo = run_fleet(spec.models, FleetPolicy(), signals)
    assert int(np.asarray(coop.n_peer_out).sum()) == 0
    assert int(np.asarray(coop.n_success).sum()) == \
        int(np.asarray(silo.n_success).sum())


# ---------------------------------------------------------------------------
# (3) handover re-homes a roaming drone's arrivals
# ---------------------------------------------------------------------------

HANDOVER = ScenarioSpec(
    name="handover", duration_ms=60_000.0,
    edges=(EdgeSite(0, 0, radius=1_100.0),
           EdgeSite(2_000, 0, radius=1_100.0)),
    # 2000 m at 33.4 m/s → crosses the x=1000 midline near t = 30 s
    drones=(DroneSpec(waypoints=((0.0, 0.0), (2_000.0, 0.0)),
                      speed_mps=33.4),))


def test_handover_rehomes_arrivals_in_oracle():
    compiled = compile_oracle(HANDOVER)
    t0 = [a.time for a in compiled.edge_arrivals[0]]
    t1 = [a.time for a in compiled.edge_arrivals[1]]
    assert t0 and t1
    assert max(t0) < 31_000.0 <= min(t1) + 2_000.0     # split near 30 s
    assert max(t0) < min(t1)                           # clean handover
    run = run_scenario_oracle(HANDOVER, "DEMS")
    assert all(r.completed > 0 for r in run.per_edge)
    assert run.merged.generated == len(t0) + len(t1)


def test_handover_rehomes_arrivals_in_fleet_sim():
    signals = compile_fleet(HANDOVER, dt=25.0)
    arrive = np.asarray(signals.arrive)                # [T, E, M]
    times = np.asarray(signals.times)
    e0_times = times[arrive[:, 0].any(-1)]
    e1_times = times[arrive[:, 1].any(-1)]
    assert e0_times.size and e1_times.size
    assert e0_times.max() < e1_times.min()             # re-homed, not mixed
    final = run_fleet(HANDOVER.models, "DEMS", signals)
    per_edge_done = np.asarray(final.n_success).sum(-1)
    assert (per_edge_done > 0).all()


# ---------------------------------------------------------------------------
# scenario events: bursts, churn, outages, heterogeneity, registry
# ---------------------------------------------------------------------------

def test_burst_raises_arrival_count_only_inside_window():
    base = ScenarioSpec(name="b0", duration_ms=60_000.0)
    burst = dataclasses.replace(
        base, bursts=(Burst(start_ms=20_000.0, end_ms=40_000.0,
                            rate_mult=3.0),))
    n_base = len(compile_oracle(base).edge_arrivals[0])
    got = compile_oracle(burst).edge_arrivals[0]
    extra = [a for a in got if a.time not in
             {b.time for b in compile_oracle(base).edge_arrivals[0]}]
    assert len(got) > n_base
    assert all(20_000.0 <= a.time < 40_000.0 for a in extra)
    # rate_mult 3 ⇒ ~2 extra segments/s/drone over 20 s × 3 drones
    assert len(got) - n_base == pytest.approx(
        2 * 20 * 3 * len(base.model_names), rel=0.1)


def test_churn_drops_arrivals_outside_lifetime():
    spec = ScenarioSpec(
        name="c0", duration_ms=60_000.0,
        drones=(DroneSpec(despawn_ms=30_000.0),
                DroneSpec(spawn_ms=30_000.0)))
    arr = compile_oracle(spec).edge_arrivals[0]
    for a in arr:
        if a.drone == 0:
            assert a.time < 30_000.0
        else:
            assert a.time >= 30_000.0


def test_cloud_outage_hurts_oracle_completion():
    base = ScenarioSpec(name="o0", duration_ms=60_000.0)
    out = dataclasses.replace(
        base, outages=(CloudOutage(start_ms=15_000.0, end_ms=45_000.0),))
    r_base = run_scenario_oracle(base, "DEMS").merged
    r_out = run_scenario_oracle(out, "DEMS").merged
    assert r_out.generated == r_base.generated
    assert r_out.completed < r_base.completed


def test_cloud_outage_gates_fleet_dispatch():
    base = ScenarioSpec(name="o1", duration_ms=60_000.0)
    out = dataclasses.replace(
        base, outages=(CloudOutage(start_ms=15_000.0, end_ms=45_000.0),))
    s_base = fleet_summary(run_scenario_fleet(base, "DEMS"))
    s_out = fleet_summary(run_scenario_fleet(out, "DEMS"))
    assert not np.asarray(compile_fleet(out).cloud_up).all()
    assert s_out["completed"] < s_base["completed"]


def test_compile_fleet_preserves_task_count_under_bursts():
    """Coincident arrivals must spill to neighboring ticks, not collapse:
    the dense arrival mask carries exactly the oracle's task count (the
    old boolean-collapse silently deflated burst load by ~50 %)."""
    spec = get("cloud-crunch", duration_ms=120_000.0)
    n_oracle = sum(len(a) for a in compile_oracle(spec).edge_arrivals)
    n_fleet = int(np.asarray(compile_fleet(spec).arrive).sum())
    assert abs(n_fleet - n_oracle) <= 0.01 * n_oracle, (n_fleet, n_oracle)


def test_compile_fleet_bw_channel_matches_trace_and_defaults_nominal():
    from repro.sim.network import NOMINAL_BW_MBPS

    plain = compile_fleet(get("baseline", duration_ms=10_000.0))
    assert np.allclose(np.asarray(plain.bw), NOMINAL_BW_MBPS)
    fade = get("bw-fade", duration_ms=60_000.0)
    sig = compile_fleet(fade)
    bw = np.asarray(sig.bw)
    assert bw.min() >= fade.bandwidth.lo and bw.max() <= fade.bandwidth.hi
    assert bw.std() > 0.0                      # the walk actually moves
    assert (bw < NOMINAL_BW_MBPS).mean() > 0.9  # it is a deep fade


def test_hetero_edges_scale_oracle_latency_and_fleet_load_mult():
    spec = get("hetero-edges", duration_ms=30_000.0)
    fast, nominal, slow = (spec.edge_models(e) for e in range(3))
    assert fast[0].t_edge < nominal[0].t_edge < slow[0].t_edge
    lm = np.asarray(compile_fleet(spec).load_mult)
    assert np.allclose(lm[0], [0.7, 1.0, 1.6])


def test_registry_has_eight_compilable_scenarios():
    assert len(names()) >= 8
    assert {"cloud-crunch", "bw-fade"} <= set(names())
    for name in names():
        spec = get(name, duration_ms=10_000.0)
        compiled = compile_oracle(spec)
        assert len(compiled.edge_arrivals) == spec.n_edges
        assert sum(len(a) for a in compiled.edge_arrivals) > 0
        signals = compile_fleet(spec)
        assert np.asarray(signals.arrive).any()
