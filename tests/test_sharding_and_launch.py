"""Unit tests for the logical-axis rule engine and launcher helpers."""
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import (DEFAULT_RULES, logical_to_pspec,
                                   sharding_rules)


@pytest.fixture(scope="module")
def mesh2d():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh()


class FakeMesh:
    """Shape-only stand-in so we can test 16×16 rules on a 1-CPU host."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def _spec(shape, logical, mesh):
    return logical_to_pspec(shape, logical, mesh, DEFAULT_RULES)


def test_divisible_dims_shard():
    m = FakeMesh(pod=1, data=16, model=16)
    assert _spec((64, 4096), ("batch", "seq"), m) == P(("pod", "data"), None) \
        or _spec((64, 4096), ("batch", "seq"), m)[0] is not None


def test_indivisible_heads_fall_back_to_replication():
    m = FakeMesh(pod=1, data=16, model=16)
    # llava: 56 heads % 16 != 0 → heads dim replicated
    spec = _spec((2, 128, 56, 128), ("batch", "seq", "heads", "head_dim"), m)
    assert spec[2] is None
    # qwen2: 64 heads divide → sharded
    spec = _spec((2, 128, 64, 128), ("batch", "seq", "heads", "head_dim"), m)
    assert spec[2] == "model"


def test_axis_used_only_once_per_tensor():
    m = FakeMesh(pod=1, data=16, model=16)
    # both kv_seq and kv_heads want 'model'; first divisible dim wins
    spec = _spec((80, 128, 32768, 8, 128),
                 (None, "batch", "kv_seq", "kv_heads", None), m)
    assert spec[2] == "model" and spec[3] is None


def test_seq_model_fallback_for_attention_logits():
    m = FakeMesh(pod=1, data=16, model=16)
    # heads take 'model' when divisible → seq_model unused
    spec = _spec((2, 64, 4096, 4096),
                 ("batch", "heads", "seq_model", None), m)
    assert spec[1] == "model" and spec[2] is None
    # heads 56 fail → seq_model picks up the axis
    spec = _spec((2, 56, 4096, 4096),
                 ("batch", "heads", "seq_model", None), m)
    assert spec[1] is None and spec[2] == "model"


def test_missing_pod_axis_is_filtered(mesh2d):
    with sharding_rules(mesh2d):
        from repro.launch.sharding import shard
        import jax.numpy as jnp
        x = shard(jnp.zeros((jax.device_count(), 8)), "batch", "seq")
        assert x.shape == (jax.device_count(), 8)


def test_dryrun_helpers():
    from repro.launch import dryrun as D
    from repro.configs.registry import ARCHS

    # skips documented for non-SWA full-attention archs
    assert ("grok-1-314b", "long_500k") in D.SKIPS
    assert ("whisper-medium", "long_500k") in D.SKIPS
    assert ("qwen2-72b", "long_500k") not in D.SKIPS   # SWA variant runs

    # microbatching tiers
    assert D.n_micro_for(ARCHS["granite-3-2b"], "train_4k") == 1
    assert D.n_micro_for(ARCHS["qwen2-72b"], "train_4k") == 8
    assert D.n_micro_for(ARCHS["nemotron-4-340b"], "train_4k") == 16
    assert D.n_micro_for(ARCHS["nemotron-4-340b"], "decode_32k") == 1

    # the long_500k variant flips sliding_window on
    v = D.variant_for(ARCHS["qwen2-72b"], "long_500k")
    assert v.sliding_window == 8192
    assert D.variant_for(ARCHS["qwen2-72b"], "decode_32k").sliding_window == 0

    # delta units per family
    assert D.delta_unit(ARCHS["granite-3-2b"]) == 1
    assert D.delta_unit(ARCHS["xlstm-1.3b"]) == 8
    assert D.delta_unit(ARCHS["zamba2-7b"]) == 6


def test_input_specs_cover_all_families():
    from repro.launch import dryrun as D
    from repro.configs.registry import ARCHS

    for name, cfg in ARCHS.items():
        for shape in D.SHAPES:
            specs = D.input_specs(cfg, shape)
            assert "tokens" in specs or "token" in specs
            for k, v in specs.items():
                D.batch_logical(cfg, k)     # raises on unknown keys


def test_roofline_hlo_collective_parsing():
    from repro.roofline.analysis import collective_bytes, parse_collectives
    hlo = """
HloModule jit_step
%body.1 (x: f32[8]) -> f32[8] {
  %ar = bf16[256,1024]{1,0} all-reduce(%p), replica_groups={}
}
ENTRY %main (p: f32[8]) -> f32[8] {
  %ag = f32[512,512]{1,0} all-gather(%p0), dimensions={0}
  %aa = bf16[64]{0} all-to-all(%p1)
}
"""
    cols = parse_collectives(hlo)
    kinds = sorted(c.kind for c in cols)
    assert kinds == ["all-gather", "all-reduce", "all-to-all"]
    agg = collective_bytes(hlo, body_trip_count=10)
    assert agg["all-gather"] == 512 * 512 * 4
    assert agg["all-reduce"] == 256 * 1024 * 2 * 10   # body × trip count
    assert agg["all-to-all"] == 64 * 2


def test_roofline_extrapolation():
    from repro.roofline.analysis import RooflineTerms, extrapolate
    # linear: base 10, per-layer 5 → at 40 layers: 210
    assert extrapolate(15.0, 20.0, 1, 2, 40) == pytest.approx(210.0)
    t = RooflineTerms.build(flops=1.97e14, hbm_bytes=1.0, coll_bytes=1.0)
    assert t.bottleneck == "compute" and t.compute_s == pytest.approx(1.0)
