"""Scenario-fuzzing harness for the metropolis-scale sweep planner.

Random :class:`~repro.scenarios.spec.ScenarioSpec` missions —
heterogeneous edge tiers, roaming drones, arrival bursts, cloud
outages, chaos-engine faults (edge crashes, brownouts, DDoS floods),
stochastic execution durations, tight cloud concurrency — are pushed
through the three sweep lowerings and held to *bitwise* agreement:

* the shape-bucketed multi-program planner (``planner="bucketed"``,
  carry buffers donated),
* the padded single-program reference (``planner="padded"``), and
* the plain per-scenario :func:`run_scenario_fleet` loop,

and on every fuzzed mission the flight-recorder conservation ledger
must stay exact (arrived == settled + in-flight at every tick).

Exactness is non-negotiable: the planner only re-groups and re-stacks
runs, it never re-orders arithmetic inside a lane, so the comparisons
are ``==`` on the summary dicts — not ``allclose``.

The spec lattice is deliberately small (fixed horizon, 1–2 edges, the
two Table-1 model sets): repeated examples then reuse jit programs
across the run instead of paying XLA a fresh trace per example, which
keeps the harness inside a CI-friendly wall-clock budget.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the [test] extra: vendored shim
    from _minihyp import given, settings, strategies as st  # noqa: F401

from repro.core.task import ACTIVE, PASSIVE
from repro.obs.metrics import check_conservation
from repro.obs.trace import TraceSpec
from repro.scenarios import (Brownout, Burst, CloudOutage, DroneSpec,
                             DurationJitter, EdgeCrash, EdgeSite, FaultSpec,
                             Flood, ScenarioSpec, fleet_summary,
                             run_registry_sweep, run_scenario_fleet)

pytestmark = pytest.mark.fuzz

# fixed horizon: every example lands in one of a handful of shape
# buckets, so the bucketed/padded/loop programs compile once and are
# reused across examples
_DURATION_MS = 3_000.0
_SPACING_M = 2_400.0   # > default coverage radius: disjoint edge zones


@st.composite
def scenario_specs(draw):
    n_edges = draw(st.integers(1, 2))
    edges = tuple(
        EdgeSite(x=_SPACING_M * e,
                 speed_factor=draw(st.sampled_from((0.7, 1.0, 1.6))))
        for e in range(n_edges))
    # one hovering drone per edge keeps every site busy; an optional
    # roamer ping-pongs across the zone boundary (handover churn)
    drones = [DroneSpec(waypoints=((_SPACING_M * e, 0.0),))
              for e in range(n_edges)]
    if draw(st.booleans()):
        drones.append(DroneSpec(
            waypoints=((0.0, 0.0), (_SPACING_M * max(n_edges - 1, 1), 0.0)),
            speed_mps=300.0))
    bursts = ((Burst(500.0, 1_500.0,
                     rate_mult=draw(st.sampled_from((0.5, 3.0)))),)
              if draw(st.booleans()) else ())
    outages = ((CloudOutage(1_000.0, 2_000.0),)
               if draw(st.booleans()) else ())
    jitter = (DurationJitter(seed=draw(st.integers(0, 3)))
              if draw(st.booleans()) else None)
    # chaos-engine faults: same signal shapes (edge_up/link_up lanes are
    # always present), so fuzzing them costs zero extra jit traces
    faults = None
    if draw(st.booleans()):
        faults = FaultSpec(
            crashes=((EdgeCrash(edge=draw(st.integers(0, n_edges - 1)),
                                start_ms=800.0, end_ms=1_800.0),)
                     if draw(st.booleans()) else ()),
            brownouts=((Brownout(1_200.0, 2_400.0, theta_ms=250.0,
                                 ramp_ms=400.0),)
                       if draw(st.booleans()) else ()),
            floods=((Flood(600.0, 1_400.0,
                           rate_hz=draw(st.sampled_from((5.0, 20.0))),
                           seed=draw(st.integers(0, 3))),)
                    if draw(st.booleans()) else ()))
    return ScenarioSpec(
        name="fuzz", duration_ms=_DURATION_MS,
        model_names=draw(st.sampled_from((PASSIVE, ACTIVE))),
        edges=edges, drones=tuple(drones), bursts=bursts,
        outages=outages, jitter=jitter, faults=faults,
        cloud_concurrency=draw(st.sampled_from((2, 16))),
        seed=draw(st.integers(0, 3)))


def _row(d):
    """A sweep row minus its (scenario, policy, seed) tag."""
    return {k: v for k, v in d.items()
            if k not in ("scenario", "policy", "seed")}


@settings(max_examples=4, deadline=None)
@given(spec=scenario_specs(),
       policy=st.sampled_from(("DEMS-A", "GEMS-COOP")))
def test_fuzz_bucketed_padded_loop_bitwise(spec, policy):
    bucketed = run_registry_sweep([spec], (policy,), (spec.seed,),
                                  planner="bucketed", donate=True)
    padded = run_registry_sweep([spec], (policy,), (spec.seed,),
                                planner="padded")
    assert len(bucketed) == len(padded) == 1
    assert bucketed[0]["scenario"] == padded[0]["scenario"] == "fuzz"

    # the per-scenario loop, flight recorder on: its summary closes the
    # three-way parity triangle and its counters feed the ledger
    res = run_scenario_fleet(spec, policy, trace=TraceSpec(counters=True))
    loop = fleet_summary(res.final)

    assert _row(bucketed[0]) == _row(padded[0]) == loop
    check_conservation(res.counters)
