"""Dependency-free stand-in for the slice of Hypothesis this suite uses.

The property tests guard their import with ``try: import hypothesis``
and fall back to this shim, so they *run* (instead of skipping) on
containers built without the ``[test]`` extra.  It is not Hypothesis:
there is no shrinking, no example database, and no adaptive generation —
just deterministic seeded sampling of ``max_examples`` inputs per test,
with a light bias toward interval endpoints.  Failures therefore
reproduce bit-for-bit across runs, and the real package (when installed)
wins the import race unchanged.

Supported surface: ``given`` (positional and keyword strategies),
``settings(max_examples=..., deadline=...)``, and the strategies
``integers, floats, booleans, sampled_from, lists, tuples, builds,
composite``.
"""
from __future__ import annotations

import hashlib
import random
from types import SimpleNamespace


class SearchStrategy:
    def __init__(self, draw_fn, label="strategy"):
        self._draw = draw_fn
        self._label = label

    def example(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self):
        return f"minihyp.{self._label}"


def _endpoint_bias(rng, lo, hi, body):
    # ~10% of draws land exactly on an interval endpoint: cheap coverage
    # of the off-by-one territory shrinking would otherwise find
    r = rng.random()
    if r < 0.05:
        return lo
    if r < 0.10:
        return hi
    return body()


def integers(min_value, max_value):
    return SearchStrategy(
        lambda rng: _endpoint_bias(rng, min_value, max_value,
                                   lambda: rng.randint(min_value, max_value)),
        f"integers({min_value}, {max_value})")


def floats(min_value, max_value, **_kwargs):
    return SearchStrategy(
        lambda rng: _endpoint_bias(
            rng, float(min_value), float(max_value),
            lambda: rng.uniform(min_value, max_value)),
        f"floats({min_value}, {max_value})")


def booleans():
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def sampled_from(elements):
    seq = list(elements)
    return SearchStrategy(lambda rng: seq[rng.randrange(len(seq))],
                          "sampled_from")


def lists(elements, *, min_size=0, max_size=None):
    hi = min_size + 8 if max_size is None else max_size
    return SearchStrategy(
        lambda rng: [elements.example(rng)
                     for _ in range(rng.randint(min_size, hi))],
        "lists")


def tuples(*strategies):
    return SearchStrategy(
        lambda rng: tuple(s.example(rng) for s in strategies), "tuples")


def builds(target, *args, **kwargs):
    return SearchStrategy(
        lambda rng: target(*[a.example(rng) for a in args],
                           **{k: v.example(rng) for k, v in kwargs.items()}),
        f"builds({getattr(target, '__name__', target)!r})")


def composite(f):
    def builder(*args, **kwargs):
        def do_draw(rng):
            return f(lambda s: s.example(rng), *args, **kwargs)
        return SearchStrategy(do_draw, f"composite({f.__name__!r})")
    builder.__name__ = f.__name__
    return builder


strategies = SimpleNamespace(
    integers=integers, floats=floats, booleans=booleans,
    sampled_from=sampled_from, lists=lists, tuples=tuples, builds=builds,
    composite=composite, SearchStrategy=SearchStrategy)


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def apply(f):
        f._mh_max_examples = max_examples
        return f
    return apply


def given(*arg_strategies, **kw_strategies):
    def accept(f):
        # stable per-test seed: failures replay identically run to run
        base = int.from_bytes(
            hashlib.sha256(f.__qualname__.encode()).digest()[:8], "big")

        def wrapper():
            n = getattr(wrapper, "_mh_max_examples", 100)
            for i in range(n):
                rng = random.Random(base ^ (i * 0x9E3779B97F4A7C15))
                args = [s.example(rng) for s in arg_strategies]
                kwargs = {k: s.example(rng)
                          for k, s in kw_strategies.items()}
                try:
                    f(*args, **kwargs)
                except Exception as e:
                    note = (f"minihyp falsifying example #{i}: "
                            f"args={args!r} kwargs={kwargs!r}")
                    if hasattr(e, "add_note"):
                        e.add_note(note)
                    raise

        # plain zero-arg signature (no functools.wraps): pytest must not
        # see the original parameters and go hunting for fixtures
        wrapper.__name__ = f.__name__
        wrapper.__qualname__ = f.__qualname__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        wrapper.hypothesis = SimpleNamespace(inner_test=f)
        return wrapper
    return accept
