"""Chaos-engine acceptance tests (ISSUE 9).

1. fault schedules validate loudly — out-of-range windows, overlapping
   crashes, edges outside the scenario all raise ``ValueError``;
2. an *empty* ``FaultSpec`` compiles to the bitwise-identical signals as
   ``faults=None`` (the all-True availability lanes are a no-op);
3. crash / timeout semantics: a crashed edge flushes its queue as
   ``drop_crash`` and admits nothing while down; a finite
   ``cloud_give_up_ms`` turns partition-parked dispatches into
   ``drop_timeout`` in both backends;
4. fleet-vs-oracle agreement extends to hostile conditions — the new
   registry scenarios stay within 10 % on completed tasks and QoS
   (ISSUE 9 acceptance);
5. the conservation ledger is exact under every fault, alone and
   combined;
6. the shared fault lowering (floods, telemetry chaos) is deterministic.
"""
import dataclasses

import numpy as np
import pytest

from repro.faults import (Brownout, EdgeCrash, FaultSpec, Flood, Jamming,
                          Partition, TelemetryChaos)
from repro.faults.compile import flood_events, perturb_telemetry
from repro.obs.metrics import check_conservation, tail_metrics
from repro.obs.trace import TraceSpec
from repro.scenarios import (compile_fleet, fleet_summary, get,
                             run_scenario_fleet, run_scenario_oracle)
from repro.sim.fleet_jax import FleetPolicy
from repro.sim.network import EdgeLatencyModel

DET_EDGE = dict(mean_frac=0.62, sd_frac=0.0, lo_frac=0.62, hi_frac=0.62)
DET_CLOUD = dict(median_frac=0.80, sigma=1e-6, cold_start_p=0.0)


# ---------------------------------------------------------------------------
# (1) validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("build", [
    lambda: EdgeCrash(edge=-1, start_ms=0.0, end_ms=1.0),
    lambda: EdgeCrash(edge=0, start_ms=5.0, end_ms=5.0),
    lambda: Partition(start_ms=-1.0, end_ms=10.0),
    lambda: Jamming(start_ms=0.0, end_ms=10.0, bw_cap_mbps=0.0),
    lambda: Brownout(start_ms=0.0, end_ms=10_000.0, ramp_ms=6_000.0),
    lambda: Flood(start_ms=0.0, end_ms=10.0, rate_hz=0.0),
    lambda: TelemetryChaos(drop_p=1.5),
    lambda: FaultSpec(crashes=(EdgeCrash(0, 0.0, 10_000.0),
                               EdgeCrash(0, 5_000.0, 20_000.0))),
])
def test_bad_fault_specs_raise(build):
    with pytest.raises(ValueError):
        build()


def test_fault_edges_validated_against_scenario():
    spec = get("baseline")           # one edge
    with pytest.raises(ValueError, match="out of range"):
        dataclasses.replace(spec, faults=FaultSpec(
            crashes=(EdgeCrash(edge=3, start_ms=0.0, end_ms=1_000.0),)))
    with pytest.raises(ValueError, match="out of range"):
        dataclasses.replace(spec, faults=FaultSpec(
            floods=(Flood(start_ms=0.0, end_ms=1_000.0, edges=(5,)),)))


def test_bad_qoe_override_raises():
    spec = get("baseline")
    with pytest.raises(ValueError, match="qoe"):
        dataclasses.replace(spec, qoe=(1.5, 100.0))


# ---------------------------------------------------------------------------
# (2) empty schedule ≡ no schedule, bit for bit
# ---------------------------------------------------------------------------

def test_empty_fault_spec_compiles_to_identical_signals():
    calm = get("rush-hour", duration_ms=30_000.0)
    armed = dataclasses.replace(calm, faults=FaultSpec())
    a, b = compile_fleet(calm), compile_fleet(armed)
    assert a._fields == b._fields
    for name, x, y in zip(a._fields, a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y)), name
    assert bool(np.all(np.asarray(b.edge_up)))
    assert bool(np.all(np.asarray(b.link_up)))


# ---------------------------------------------------------------------------
# (3) crash and timeout semantics
# ---------------------------------------------------------------------------

def _crash_spec(duration=60_000.0):
    return dataclasses.replace(
        get("baseline", duration_ms=duration), name="crash-test",
        faults=FaultSpec(crashes=(
            EdgeCrash(edge=0, start_ms=0.3 * duration,
                      end_ms=0.6 * duration),)))


def test_crash_flushes_queue_and_blocks_admission():
    spec = _crash_spec()
    trace = TraceSpec(counters=True)
    res = run_scenario_fleet(spec, "DEMS-A", trace=trace)
    check_conservation(res.counters)
    tail = tail_metrics(res.counters, trace)
    assert tail["drops_by_cause"]["crash"] > 0
    # no edge admissions and no edge executions while the edge is down
    sig = compile_fleet(spec)
    down = ~np.asarray(sig.edge_up)[:, 0]
    admit = np.asarray(res.counters.admit_edge)[:, 0]
    execd = np.asarray(res.counters.edge_exec)[:, 0]
    assert down.any()
    assert int(admit[down].sum()) == 0
    assert int(execd[down].sum()) == 0
    # the crash hurts: strictly fewer completions than the calm twin
    calm = fleet_summary(run_scenario_fleet(
        dataclasses.replace(spec, faults=None), "DEMS-A"))
    assert fleet_summary(res.final)["completed"] < calm["completed"]


def _partition_spec(duration=60_000.0):
    return dataclasses.replace(
        get("baseline", duration_ms=duration), name="partition-test",
        faults=FaultSpec(partitions=(
            Partition(start_ms=0.2 * duration, end_ms=0.8 * duration),)))


def test_cloud_give_up_drops_partition_parked_tasks():
    spec = _partition_spec()
    pol = dataclasses.replace(FleetPolicy.from_name("DEMS-A"),
                              cloud_give_up_ms=2_000.0)
    trace = TraceSpec(counters=True)
    res = run_scenario_fleet(spec, pol, trace=trace)
    check_conservation(res.counters)
    tail = tail_metrics(res.counters, trace)
    assert tail["drops_by_cause"]["timeout"] > 0
    # +inf give-up on the same mission never times out
    res_inf = run_scenario_fleet(spec, "DEMS-A", trace=trace)
    tail_inf = tail_metrics(res_inf.counters, trace)
    assert tail_inf["drops_by_cause"]["timeout"] == 0


def test_cloud_give_up_agrees_with_oracle():
    spec = _partition_spec()
    give_up = 2_000.0
    pol = dataclasses.replace(FleetPolicy.from_name("DEMS-A"),
                              cloud_give_up_ms=give_up)
    fleet = fleet_summary(run_scenario_fleet(spec, pol))
    oracle = run_scenario_oracle(
        spec, "DEMS-A", cloud_give_up_ms=give_up,
        edge_model=EdgeLatencyModel(**DET_EDGE),
        cloud_model_overrides=DET_CLOUD).merged
    d_done = abs(fleet["completed"] - oracle.completed) / oracle.completed
    assert d_done < 0.10, (fleet["completed"], oracle.completed)


# ---------------------------------------------------------------------------
# (4) hostile fleet-vs-oracle agreement (ISSUE 9 acceptance: < 10 % on
#     the new registry scenarios for DEMS-A and GEMS-COOP)
# ---------------------------------------------------------------------------

def _agreement(spec, policy):
    oracle = run_scenario_oracle(
        spec, policy, edge_model=EdgeLatencyModel(**DET_EDGE),
        cloud_model_overrides=DET_CLOUD).merged
    fleet = fleet_summary(run_scenario_fleet(spec, policy))
    d_done = abs(fleet["completed"] - oracle.completed) / oracle.completed
    d_qos = abs(fleet["qos_utility"] - oracle.qos_utility) / \
        abs(oracle.qos_utility)
    return fleet, oracle, d_done, d_qos


@pytest.mark.parametrize("policy", ["DEMS-A", "GEMS-COOP"])
@pytest.mark.parametrize("scenario", ["flash-crowd", "ddos-flood",
                                      "partition"])
def test_hostile_scenarios_fleet_matches_oracle(scenario, policy):
    spec = get(scenario, duration_ms=60_000.0)
    fleet, oracle, d_done, d_qos = _agreement(spec, policy)
    assert d_done < 0.10, (scenario, policy, fleet["completed"],
                           oracle.completed)
    assert d_qos < 0.10, (scenario, policy, fleet["qos_utility"],
                          oracle.qos_utility)


def test_brownout_fleet_matches_oracle():
    # the registry brownout (ACTIVE workload, +350 ms plateau) pushes
    # its heavyweight models (CD/DEO) to the feasibility boundary, where
    # GEMS decisions legitimately flip on tick-vs-event quantization —
    # so DEMS-A is held to the strict bound on the registry scenario
    # and GEMS-COOP on the PASSIVE variant of the same brownout
    fleet, oracle, d_done, d_qos = _agreement(
        get("brownout", duration_ms=60_000.0), "DEMS-A")
    assert d_done < 0.10, (fleet["completed"], oracle.completed)
    assert d_qos < 0.10, (fleet["qos_utility"], oracle.qos_utility)

    from repro.core.task import PASSIVE
    passive = dataclasses.replace(get("brownout", duration_ms=60_000.0),
                                  model_names=PASSIVE, qoe=None)
    fleet, oracle, d_done, d_qos = _agreement(passive, "GEMS-COOP")
    assert d_done < 0.10, (fleet["completed"], oracle.completed)
    assert d_qos < 0.10, (fleet["qos_utility"], oracle.qos_utility)


# ---------------------------------------------------------------------------
# (5) conservation under combined faults; streaming equivalence
# ---------------------------------------------------------------------------

def _combined_spec(duration=60_000.0):
    return dataclasses.replace(
        get("rush-hour", duration_ms=duration), name="combined-chaos",
        faults=FaultSpec(
            crashes=(EdgeCrash(edge=1, start_ms=0.3 * duration,
                               end_ms=0.5 * duration),),
            partitions=(Partition(start_ms=0.5 * duration,
                                  end_ms=0.7 * duration, edges=(0,)),),
            jamming=(Jamming(start_ms=0.1 * duration,
                             end_ms=0.3 * duration, edges=(1,)),),
            brownouts=(Brownout(start_ms=0.2 * duration,
                                end_ms=0.9 * duration, theta_ms=250.0,
                                ramp_ms=5_000.0),),
            floods=(Flood(start_ms=0.4 * duration, end_ms=0.8 * duration,
                          rate_hz=8.0, edges=(0,)),)))


@pytest.mark.parametrize("policy", ["DEMS-A", "GEMS-COOP"])
def test_conservation_exact_under_combined_faults(policy):
    spec = _combined_spec()
    trace = TraceSpec(counters=True)
    res = run_scenario_fleet(spec, policy, trace=trace)
    check_conservation(res.counters)
    tail = tail_metrics(res.counters, trace)
    assert tail["drops_by_cause"]["crash"] > 0


def test_streaming_equivalence_under_combined_faults():
    from repro.scenarios.runner import assert_streaming_equivalence

    spec = _combined_spec(duration=30_000.0)
    summary = assert_streaming_equivalence(spec, "DEMS-A")
    assert summary["completed"] > 0


# ---------------------------------------------------------------------------
# (6) deterministic shared lowering
# ---------------------------------------------------------------------------

def test_flood_events_deterministic_and_windowed():
    faults = FaultSpec(floods=(
        Flood(start_ms=10_000.0, end_ms=20_000.0, rate_hz=10.0,
              edges=(1,), seed=4),))
    a = flood_events(7, faults, n_edges=2, n_models=4,
                     duration_ms=60_000.0, n_drones=3)
    b = flood_events(7, faults, n_edges=2, n_models=4,
                     duration_ms=60_000.0, n_drones=3)
    assert len(a) == 100                      # 10 Hz × 10 s
    assert all(x[:3] == y[:3] and np.array_equal(x[3], y[3])
               for x, y in zip(a, b))
    for t, drone, edge, order in a:
        assert 10_000.0 <= t < 20_000.0
        assert drone == 3                     # attacker id past the fleet
        assert edge == 1
        assert sorted(order) == [0, 1, 2, 3]
    # a different scenario seed draws a different flood
    c = flood_events(8, faults, n_edges=2, n_models=4,
                     duration_ms=60_000.0, n_drones=3)
    assert [x[0] for x in a] != [x[0] for x in c]


def test_flood_events_clip_to_duration():
    faults = FaultSpec(floods=(
        Flood(start_ms=50_000.0, end_ms=90_000.0, rate_hz=10.0),))
    evs = flood_events(0, faults, n_edges=1, n_models=4,
                       duration_ms=60_000.0)
    assert len(evs) == 100                    # clipped to [50 s, 60 s)
    assert all(t < 60_000.0 for t, *_ in evs)
    assert flood_events(0, faults, n_edges=1, n_models=4,
                        duration_ms=40_000.0) == []


def test_perturb_telemetry_at_least_once_and_deterministic():
    events = [(float(i) * 10.0, i) for i in range(200)]
    chaos = TelemetryChaos(drop_p=0.0, dup_p=0.3, reorder_p=0.4,
                           max_delay_ms=150.0, seed=5)
    a = perturb_telemetry(events, chaos)
    b = perturb_telemetry(events, chaos)
    assert a == b
    # at-least-once with drop_p=0: every event survives, some twice
    assert len(a) >= len(events)
    assert {ev[1] for ev in a} == set(range(200))
    assert any(a.count(ev) == 2 for ev in events)
    # reordering actually happened
    assert [ev[1] for ev in a] != sorted(ev[1] for ev in a)


def test_perturb_telemetry_drops():
    events = [(float(i), i) for i in range(500)]
    out = perturb_telemetry(events, TelemetryChaos(drop_p=0.5, seed=1))
    assert 100 < len(out) < 400
