"""Unit tests for the paper's task/utility model and scheduling policies."""
import numpy as np
import pytest

from repro.core.task import (ACTIVE, PASSIVE, TABLE1, ModelProfile, Outcome,
                             Task, migration_score, table2)
from repro.core.schedulers import (ALL_POLICIES, AdaptiveEstimator,
                                   CloudAccept, Policy, make_policy)
from repro.sim.engine import Arrival, Simulator, run_policy
from repro.sim.network import (CloudLatencyModel, EdgeLatencyModel,
                               cellular_bandwidth_trace, constant, trapezium,
                               transfer_ms)
from repro.sim.workloads import STANDARD_WORKLOADS, gems_workload, standard


# ---------------------------------------------------------------------------
# Table 1 identities (γ^E = β − K, γ^C = β − K̂) — paper footnote 3.
# ---------------------------------------------------------------------------

EXPECTED_GAMMAS = {  # from Table 1's γ^E / γ^C columns
    "HV": (124, 100), "DEV": (99, 74), "MD": (74, 50),
    "BP": (38, -3), "CD": (171, 23), "DEO": (244, 40),
}


@pytest.mark.parametrize("name", list(TABLE1))
def test_table1_gamma_columns(name):
    m = TABLE1[name]
    ge, gc = EXPECTED_GAMMAS[name]
    assert m.gamma_edge == ge
    assert m.gamma_cloud == gc


def test_bp_is_the_negative_cloud_utility_model():
    negatives = [n for n, m in TABLE1.items() if m.gamma_cloud <= 0]
    assert negatives == ["BP"]


def test_utility_eqn1_cases():
    m = TABLE1["HV"]
    t = Task(uid=1, model=m, created=0.0)
    for outcome, expect in [
            (Outcome.EDGE_SUCCESS, m.beta - m.cost_edge),
            (Outcome.EDGE_MISS, -m.cost_edge),
            (Outcome.CLOUD_SUCCESS, m.beta - m.cost_cloud),
            (Outcome.CLOUD_MISS, -m.cost_cloud),
            (Outcome.DROPPED, 0.0)]:
        t.outcome = outcome
        assert t.utility() == expect


def test_migration_score_eqn3():
    m = TABLE1["HV"]
    assert migration_score(m, cloud_feasible=True) == m.gamma_edge - m.gamma_cloud
    assert migration_score(m, cloud_feasible=False) == m.gamma_edge
    bp = TABLE1["BP"]   # γ^C ≤ 0 → score is γ^E even if feasible
    assert migration_score(bp, cloud_feasible=True) == bp.gamma_edge


def test_table2_workloads():
    wl1 = table2("WL1", alpha=0.9)
    assert [m.name for m in wl1] == ["HV", "DEV", "MD", "CD"]
    hv = wl1[0]
    assert (hv.qoe_beta, hv.deadline, hv.t_edge, hv.t_cloud) == (360, 400, 100, 200)
    assert hv.beta == TABLE1["HV"].beta            # QoS β retained
    wl2 = table2("WL2", alpha=1.0)
    cd = [m for m in wl2 if m.name == "CD"][0]
    assert (cd.deadline, cd.t_edge, cd.t_cloud) == (1000, 750, 950)
    with pytest.raises(ValueError):
        table2("WL3", 0.9)


# ---------------------------------------------------------------------------
# Policy admission / ordering logic
# ---------------------------------------------------------------------------

def _task(name="HV", created=0.0, uid=1):
    return Task(uid=uid, model=TABLE1[name], created=created)


def test_edf_priority_is_absolute_deadline():
    p = make_policy("EDF-E+C")
    t = _task("HV", created=100.0)
    assert p.edge_key(t) == 100.0 + TABLE1["HV"].deadline


def test_cloud_rejects_infeasible_and_negative():
    p = make_policy("EDF-E+C")
    t = _task("HV", created=0.0)
    # infeasible: now too late for the cloud latency
    acc = p.offer_cloud(t, now=t.abs_deadline - 10, t_cloud=t.model.t_cloud)
    assert not acc.accept
    # negative cloud utility (BP) rejected without stealing
    bp = _task("BP")
    assert not p.offer_cloud(bp, now=0.0, t_cloud=bp.model.t_cloud).accept


def test_dems_parks_negative_utility_for_stealing():
    p = make_policy("DEMS")
    bp = _task("BP")
    acc = p.offer_cloud(bp, now=0.0, t_cloud=bp.model.t_cloud)
    assert acc.accept and acc.steal_only
    # trigger is the latest time it could still start on the edge (§5.3)
    assert acc.trigger == bp.abs_deadline - bp.model.t_edge


def test_dems_trigger_time_defers_positive_tasks():
    p = make_policy("DEMS")
    hv = _task("HV")
    acc = p.offer_cloud(hv, now=0.0, t_cloud=hv.model.t_cloud)
    assert acc.accept and not acc.steal_only
    assert acc.trigger == pytest.approx(
        hv.abs_deadline - hv.model.t_cloud - p.cloud_margin)


def test_fifo_cloud_for_non_stealing_policies():
    p = make_policy("EDF-E+C")
    hv = _task("HV")
    acc = p.offer_cloud(hv, now=5.0, t_cloud=hv.model.t_cloud)
    assert acc.accept and acc.trigger == 5.0


def test_migration_decision_prefers_keeping_higher_scores():
    p = make_policy("DEM")
    new = _task("CD")      # S = γE−γC = 148 when cloud-feasible
    victims = [_task("HV", uid=2)]   # S = 24
    assert p.migration_decision(new, victims, 0.0, lambda m: m.t_cloud)
    # reversed: victim CD (148) outweighs new HV (24) → keep victims
    assert not p.migration_decision(
        _task("HV"), [_task("CD", uid=3)], 0.0, lambda m: m.t_cloud)


# ---------------------------------------------------------------------------
# DEMS-A adaptive estimator (§5.4)
# ---------------------------------------------------------------------------

def test_adaptive_estimator_inflates_and_cools():
    est = AdaptiveEstimator(static=400.0, w=4, eps=10.0, t_cp=10_000.0)
    assert est.current == 400.0
    for _ in range(4):
        est.observe(800.0)
    assert est.current == pytest.approx(800.0)
    # skipping tasks for longer than the cooling period resets the estimate
    est.on_skip(now=0.0)
    est.on_skip(now=5_000.0)
    assert est.current == pytest.approx(800.0)
    est.on_skip(now=10_001.0)
    assert est.current == 400.0


def test_adaptive_estimator_ignores_small_excursions():
    est = AdaptiveEstimator(static=400.0, w=10, eps=10.0)
    for _ in range(10):
        est.observe(405.0)
    assert est.current == 400.0


def test_adaptive_window_is_circular():
    est = AdaptiveEstimator(static=100.0, w=3, eps=1.0)
    for v in (500.0, 500.0, 500.0, 100.0, 100.0, 100.0):
        est.observe(v)
    # after the buffer fully turns over, only the recent values matter, but
    # the estimate never adapts downward except via cooling reset (§5.4)
    assert est.current == pytest.approx(500.0)


# ---------------------------------------------------------------------------
# Simulator end-to-end behaviour
# ---------------------------------------------------------------------------

def _run(policy_name, workload="3D-A", seed=7, **kw):
    return run_policy(make_policy(policy_name), standard(workload, seed=1),
                      300_000.0, seed=seed, **kw)


def test_all_policies_run_and_conserve_tasks():
    arr = standard("2D-P", seed=0)
    for name in ALL_POLICIES:
        r = run_policy(make_policy(name), arr, 300_000.0, seed=3)
        assert r.generated == len(arr)
        for st in r.per_model.values():
            total = (st.edge_success + st.edge_miss + st.cloud_success
                     + st.cloud_miss + st.dropped)
            assert total == st.generated, f"{name}: task leak"


def test_cld_drops_bp_and_completes_the_rest():
    r = _run("CLD")
    bp = r.per_model["BP"]
    assert bp.completed == 0 and bp.dropped == bp.generated
    assert r.completion_rate > 0.70


def test_edge_only_saturates_under_heavy_load():
    r_light = _run("EDF", workload="2D-P")
    r_heavy = _run("EDF", workload="4D-A")
    assert r_light.completion_rate > r_heavy.completion_rate
    assert r_heavy.edge_utilization > 0.7


def test_dems_beats_e_plus_c_on_utility():
    e = _run("EDF-E+C")
    d = _run("DEMS")
    assert d.qos_utility > e.qos_utility
    assert d.completion_rate >= 0.95 * e.completion_rate


def test_dems_work_stealing_recovers_bp_tasks():
    r = _run("DEMS", workload="4D-P")
    assert r.stolen > 0
    # BP (the negative-cloud-utility model) is the most-stolen model (§8.4)
    others = max(st.stolen for n, st in r.per_model.items() if n != "BP")
    assert r.per_model["BP"].stolen >= others


def test_dems_migration_occurs():
    assert _run("DEMS").migrated > 0


def test_dems_a_improves_under_latency_variability():
    cm = CloudLatencyModel(latency_at=trapezium())
    base = run_policy(make_policy("DEMS"), standard("4D-P", seed=1),
                      300_000.0, seed=5, cloud_model=cm)
    adpt = run_policy(make_policy("DEMS-A"), standard("4D-P", seed=1),
                      300_000.0, seed=5, cloud_model=cm)
    assert adpt.qos_utility > base.qos_utility


def test_gems_reschedules_lagging_models():
    em = EdgeLatencyModel(mean_frac=1.0, sd_frac=0.02, lo_frac=0.95,
                          hi_frac=1.1, spike_p=0.04, spike_mult=1.6)
    cm = CloudLatencyModel(median_frac=0.92, sigma=0.06)
    arr = gems_workload("WL2", alpha=1.0, n_drones=3, seed=2)
    g = run_policy(make_policy("GEMS"), arr, 300_000.0, seed=42,
                   edge_model=em, cloud_model=cm, cloud_concurrency=6)
    d = run_policy(make_policy("DEMS"), arr, 300_000.0, seed=42,
                   edge_model=em, cloud_model=cm, cloud_concurrency=6)
    assert g.gems_rescheduled > 50
    assert d.gems_rescheduled == 0
    assert g.total_utility > d.total_utility


def test_qoe_windows_accounted():
    arr = gems_workload("WL1", alpha=0.9, n_drones=2, seed=0)
    r = run_policy(make_policy("GEMS"), arr, 300_000.0, seed=1)
    st = r.per_model["HV"]
    assert st.windows_total > 0
    assert st.qoe_utility == st.windows_met * 360


def test_utility_accounting_consistency():
    r = _run("DEMS")
    assert r.qos_utility == pytest.approx(r.edge_utility + r.cloud_utility)
    assert r.total_utility == pytest.approx(r.qos_utility + r.qoe_utility)


def test_deterministic_given_seed():
    a = _run("DEMS", seed=11)
    b = _run("DEMS", seed=11)
    assert a.qos_utility == b.qos_utility and a.completed == b.completed


# ---------------------------------------------------------------------------
# Network models
# ---------------------------------------------------------------------------

def test_trapezium_waveform():
    th = trapezium()
    assert th(0) == 0 and th(75_000) == pytest.approx(200.0)
    assert th(150_000) == 400.0 and th(225_000) == pytest.approx(200.0)
    assert th(300_000) == 0.0


def test_cellular_trace_bounded():
    bw = cellular_bandwidth_trace(seed=3)
    vals = [bw(t) for t in np.linspace(0, 600_000, 500)]
    assert min(vals) >= 0.25 and max(vals) <= 40.0
    assert np.std(vals) > 1.0    # actually varies


def test_transfer_time():
    assert transfer_ms(38.0, 10.0) == pytest.approx(30.4)


def test_cloud_sampler_tail_calibration():
    cm = CloudLatencyModel(cold_start_p=0.0)
    rng = np.random.default_rng(0)
    s = np.array([cm.sample(rng, 400.0, 0.0) for _ in range(4000)])
    assert 0.02 < np.mean(s > 400.0) < 0.12   # ~p95 estimate
    assert np.median(s) < 400.0


def test_workload_counts_match_paper():
    # §8.3: 2D-P → 2400, 3D-A → 5400, 4D-A → 7200 tasks per base station
    assert len(standard("2D-P")) == 2400
    assert len(standard("3D-A")) == 5400
    assert len(standard("4D-A")) == 7200


def test_gems_b_dominates_gems_when_windows_unwinnable():
    """Beyond-paper GEMS-B: at α=1.0 with a constrained cloud the
    winnability guard must not do worse than GEMS on QoE."""
    em = EdgeLatencyModel(mean_frac=1.0, sd_frac=0.02, lo_frac=0.95,
                          hi_frac=1.1, spike_p=0.04, spike_mult=1.6)
    cm = CloudLatencyModel(median_frac=0.92, sigma=0.06)
    arr = gems_workload("WL2", alpha=1.0, n_drones=3, seed=2)
    qoe = {}
    for pol in ("GEMS", "GEMS-B"):
        rs = [run_policy(make_policy(pol), arr, 300_000.0, seed=100 + s,
                         edge_model=em, cloud_model=cm,
                         cloud_concurrency=6) for s in range(3)]
        qoe[pol] = np.median([r.qoe_utility for r in rs])
    assert qoe["GEMS-B"] >= qoe["GEMS"]


def test_gems_b_equals_gems_when_windows_winnable():
    arr = gems_workload("WL1", alpha=0.5, n_drones=2, seed=0)
    a = run_policy(make_policy("GEMS"), arr, 120_000.0, seed=1)
    b = run_policy(make_policy("GEMS-B"), arr, 120_000.0, seed=1)
    assert b.qoe_utility == a.qoe_utility
