"""Online control plane: FleetController, windows, checkpoints, restart.

The contract under test is ROADMAP item 2's seam: the streaming
controller is the *same computation* as batch replay — window-by-window
``step_chunk`` over telemetry-built signals must reproduce ``run_fleet``
bit for bit, checkpoint/restore must resume it exactly, and the
incremental window builder must compile registry scenarios identically
to the one-shot compiler.
"""
import os

import jax
import numpy as np
import pytest

from repro.obs.trace import TraceSpec
from repro.scenarios.compile import SignalWindowBuilder, compile_fleet
from repro.scenarios.registry import get
from repro.scenarios.runner import (assert_streaming_equivalence,
                                    fleet_summary, run_scenario_fleet,
                                    stream_scenario_fleet)
from repro.serve.controller import FleetController, drive_stream
from repro.sim import network
from repro.sim.fleet_jax import run_fleet, slice_signals


def _leaves_equal(a, b) -> list:
    """Names of EdgeState fields whose leaves differ bitwise."""
    from repro.sim.fleet_jax import EdgeState
    return [name for name, x, y in zip(EdgeState._fields, a, b)
            if not all(np.array_equal(np.asarray(u), np.asarray(v))
                       for u, v in zip(jax.tree.leaves(x),
                                       jax.tree.leaves(y)))]


# ---------------------------------------------------------------------------
# SignalWindowBuilder


def test_builder_compiler_mode_matches_compile_fleet():
    # compile_fleet now *is* the builder's horizon mode; pin one scenario
    # against a hand-rolled dense compilation of the same spec
    spec = get("rush-hour", duration_ms=5000)
    sig = compile_fleet(spec)
    n_ticks = int(sig.times.shape[0])
    assert n_ticks == 200
    assert np.asarray(sig.times)[-1] == pytest.approx((n_ticks - 1) * 25.0)
    # task count is exact: every emitted arrival lands somewhere
    assert int(np.asarray(sig.arrive).sum()) > 0


def test_builder_streaming_spill_and_hold():
    b = SignalWindowBuilder(2, 3, dt=25.0)
    assert b.add_arrival(10.0, 0, 1) == 0
    assert b.add_arrival(12.0, 0, 1) == 1       # same cell -> next tick
    assert b.add_arrival(12.0, 1, 1) == 0       # other edge unaffected
    b.set_bandwidth(50.0, 12.5, edge=1)
    b.set_theta(100.0, 80.0)
    b.set_cloud_up(75.0, False)
    w = b.emit_window(5)
    assert np.asarray(w.arrive)[:, 0, 1].tolist() == [
        True, True, False, False, False]
    assert np.asarray(w.bw)[1, 1] == network.NOMINAL_BW_MBPS
    assert np.asarray(w.bw)[2, 1] == 12.5
    assert np.asarray(w.cloud_up).tolist() == [True, True, True, False,
                                               False]
    # held values persist into the next window; late events clamp forward
    w2 = b.emit_window(3)
    assert np.asarray(w2.bw)[0, 1] == 12.5
    assert np.asarray(w2.theta)[0, 0] == 80.0
    assert not np.asarray(w2.cloud_up).any()
    assert b.add_arrival(0.0, 1, 0) == b.cursor


def test_builder_order_lane_restart_invariant():
    # the per-tick seeded order draw must not depend on window splits or
    # the builder's start tick, or a restarted controller would schedule
    # same-tick arrivals differently than the uninterrupted one
    a = SignalWindowBuilder(3, 4, order_seed=9)
    o_a = np.concatenate([np.asarray(a.emit_window(5).order),
                          np.asarray(a.emit_window(7).order)])
    b = SignalWindowBuilder(3, 4, order_seed=9, start_tick=4)
    o_b = np.asarray(b.emit_window(8).order)
    assert np.array_equal(o_a[4:], o_b)


def test_builder_refuses_to_rewrite_emitted_past():
    b = SignalWindowBuilder(1, 2)
    b.emit_window(4)
    with pytest.raises(ValueError, match="emit cursor"):
        b.load_dense("theta", np.zeros((2, 1), np.float32), start_tick=1)


# ---------------------------------------------------------------------------
# replay-vs-streaming equivalence


@pytest.mark.parametrize("scenario,policy,window", [
    ("baseline", "DEMS-A", 16),
    ("rush-hour", "GEMS", 7),          # ragged final window
    ("flaky-cloud", "DEMS-COOP", 13),  # cooperative peer offload
])
def test_streaming_matches_replay_bitwise(scenario, policy, window):
    spec = get(scenario, duration_ms=5000)
    assert_streaming_equivalence(spec, policy, window_ticks=window)


def test_streaming_equivalence_hook_detects_drift():
    # the hook must actually bite: perturb the streamed state and expect
    # the assertion to name the diverging field
    spec = get("baseline", duration_ms=2000)
    ctl = stream_scenario_fleet(spec, "DEMS")
    ref = run_scenario_fleet(spec, "DEMS")
    assert _leaves_equal(ref, ctl.state) == []
    bad = ctl.state._replace(n_success=ctl.state.n_success + 1)
    assert _leaves_equal(ref, bad) == ["n_success"]


def test_streamed_decisions_conserve_arrivals():
    spec = get("rush-hour", duration_ms=5000)
    sig = compile_fleet(spec)
    ctl = FleetController(spec.models, "DEMS-A", n_edges=spec.n_edges,
                          window_ticks=16,
                          cloud_slots=spec.cloud_concurrency)
    T = int(sig.times.shape[0])
    recs = []
    for lo in range(0, T, 16):
        recs.extend(ctl.step_signals(slice_signals(sig, lo,
                                                   min(lo + 16, T))))
    assert len(recs) == T
    assert sum(r["arrivals"] for r in recs) == int(
        np.asarray(sig.arrive).sum())
    s = ctl.summary()
    assert sum(r["hit"] for r in recs) == s["completed"]
    assert sum(r["drop"] for r in recs) == s["dropped"]


# ---------------------------------------------------------------------------
# live ingestion + checkpoint/restore


def _feed(ctl: FleetController, lo_ms: float, hi_ms: float,
          n_models: int) -> None:
    """Deterministic synthetic telemetry stream over [lo_ms, hi_ms)."""
    t = int(lo_ms)
    while t < hi_ms:
        ctl.submit(float(t), t % ctl.n_edges, (t // 40) % n_models)
        if t % 400 == 0:
            ctl.observe_bandwidth(float(t), 18.0 + (t % 1200) / 100.0,
                                  edge=0)
        if t % 1000 == 0:
            ctl.observe_theta(float(t), float(t % 3000) / 20.0)
        t += 40


def test_checkpoint_roundtrip(tmp_path):
    spec = get("baseline", duration_ms=4000)
    path = os.path.join(tmp_path, "ck")
    ctl = FleetController(spec.models, "DEMS-A", n_edges=2,
                          window_ticks=8, checkpoint_path=path)
    _feed(ctl, 0, 4000, len(spec.models))
    ctl.poll(4000.0)
    ctl.checkpoint()
    assert os.path.exists(path + ".npz")
    assert os.path.exists(path + ".tree.json")

    fresh = FleetController(spec.models, "DEMS-A", n_edges=2,
                            window_ticks=8, checkpoint_path=path)
    assert _leaves_equal(fresh.state, ctl.state) != []   # actually moved
    tick = fresh.restore()
    assert tick == ctl.tick
    assert _leaves_equal(fresh.state, ctl.state) == []
    assert fresh.summary() == ctl.summary()


def test_kill_restore_resumes_identically(tmp_path):
    # a controller killed mid-run and restored from its checkpoint must
    # finish with the same summary (and bitwise state) as an
    # uninterrupted controller over the same telemetry
    spec = get("baseline", duration_ms=6000)
    m = len(spec.models)
    kw = dict(n_edges=2, window_ticks=8)

    a = FleetController(spec.models, "DEMS-A", **kw)
    _feed(a, 0, 6000, m)
    a.poll(6000.0)
    a.close()

    path = os.path.join(tmp_path, "ck")
    b = FleetController(spec.models, "DEMS-A", checkpoint_path=path, **kw)
    _feed(b, 0, 3000, m)
    b.poll(3000.0)
    b.checkpoint()
    killed_at = b.tick
    del b                                   # the crash

    c = FleetController(spec.models, "DEMS-A", checkpoint_path=path, **kw)
    tick = c.restore()
    assert tick == killed_at
    # upstream replays telemetry from the checkpoint tick (the
    # at-least-once ingestion contract)
    _feed(c, tick * 25.0, 6000, m)
    c.poll(6000.0)
    c.close()

    assert _leaves_equal(a.state, c.state) == []
    assert c.summary() == a.summary()


def test_periodic_checkpointing(tmp_path):
    spec = get("baseline", duration_ms=3000)
    path = os.path.join(tmp_path, "auto")
    ctl = FleetController(spec.models, "DEMS", n_edges=2, window_ticks=8,
                          checkpoint_path=path, checkpoint_every=2)
    _feed(ctl, 0, 3000, len(spec.models))
    ctl.poll(3000.0)
    assert ctl.checkpoints_written >= 1
    assert os.path.exists(path + ".npz")


# ---------------------------------------------------------------------------
# serve-facing surface


def test_metrics_snapshot_shape():
    spec = get("baseline", duration_ms=3000)
    ctl = FleetController(spec.models, "DEMS-A", n_edges=2, window_ticks=8)
    _feed(ctl, 0, 3000, len(spec.models))
    ctl.poll(3000.0)
    ctl.close()
    snap = ctl.metrics_snapshot()
    for key in ("now_ms", "tick", "policy", "completed", "missed",
                "dropped", "completion_rate", "step_latency_ms",
                "ingest_to_decision_ms", "eq_depth", "cq_depth",
                "slots_busy", "latency_ms", "slack_ms", "windows_run"):
        assert key in snap, key
    assert snap["policy"] == "DEMS-A"
    assert snap["windows_run"] == ctl.windows_run > 0
    assert snap["step_latency_ms"]["p50"] is not None
    assert snap["completed"] + snap["missed"] + snap["dropped"] > 0


def test_poll_only_steps_complete_windows():
    spec = get("baseline", duration_ms=3000)
    ctl = FleetController(spec.models, "DEMS", n_edges=2, window_ticks=8)
    ctl.submit(0.0, 0, 0)
    assert ctl.poll(100.0) == []            # 4 ticks < one 8-tick window
    assert ctl.tick == 0
    recs = ctl.poll(225.0)                  # 9 ticks -> one window steps
    assert ctl.tick == 8 and len(recs) == 8
    # the ragged remainder only flushes on close()
    ctl.submit(210.0, 0, 1)
    assert ctl.poll(225.0) == []
    assert len(ctl.close()) == 1


def test_drive_stream_virtual_time():
    spec = get("baseline", duration_ms=2000)
    ctl = FleetController(spec.models, "DEMS-A", n_edges=2, window_ticks=8)
    fps = {m.name: 25.0 for m in spec.models[:2]}
    snap = drive_stream(ctl, fps, 2_000.0)
    expect = sum(int(np.ceil(2_000.0 * f / 1000.0)) for f in fps.values())
    # every frame was scheduled; some may still sit in a queue at close
    assert sum(r["arrivals"] for r in ctl.decisions) == expect
    settled = snap["completed"] + snap["missed"] + snap["dropped"]
    assert 0 < settled <= expect
    assert snap["now_ms"] == 2_000.0


def test_trace_off_controller_still_steps():
    spec = get("baseline", duration_ms=2000)
    ctl = FleetController(spec.models, "DEMS", n_edges=2, window_ticks=8,
                          trace=TraceSpec())
    _feed(ctl, 0, 2000, len(spec.models))
    assert ctl.poll(2000.0) == []           # no counters -> no records
    ctl.close()
    assert ctl.summary()["completed"] > 0
    snap = ctl.metrics_snapshot()
    assert "latency_ms" not in snap         # histograms need the recorder


# ---------------------------------------------------------------------------
# chunked replay (the thin-loop refactor itself)


def test_run_fleet_chunked_bitwise_identical():
    spec = get("rush-hour", duration_ms=5000)
    sig = compile_fleet(spec)
    whole = run_fleet(spec.models, "DEMS-A", sig)
    chunked = run_fleet(spec.models, "DEMS-A", sig, chunk_ticks=16)
    assert _leaves_equal(whole, chunked) == []


def test_run_fleet_chunked_trace_concatenates():
    spec = get("baseline", duration_ms=2000)
    sig = compile_fleet(spec)
    tspec = TraceSpec(counters=True, t_hat=True)
    whole = run_fleet(spec.models, "DEMS-A", sig, trace=tspec)
    chunked = run_fleet(spec.models, "DEMS-A", sig, trace=tspec,
                        chunk_ticks=13)
    assert _leaves_equal(whole.final, chunked.final) == []
    assert np.array_equal(np.asarray(whole.t_hat),
                          np.asarray(chunked.t_hat))
    for u, v in zip(jax.tree.leaves(whole.counters),
                    jax.tree.leaves(chunked.counters)):
        assert np.array_equal(np.asarray(u), np.asarray(v))
    assert fleet_summary(whole.final) == fleet_summary(chunked.final)


# ---------------------------------------------------------------------------
# chaos hardening: backpressure, idempotent replay, restore under faults


def test_backpressure_reject_sheds_and_recovers():
    spec = get("baseline", duration_ms=3000)
    ctl = FleetController(spec.models, "DEMS-A", n_edges=2,
                          window_ticks=8, max_pending_ticks=16,
                          shed_policy="reject")
    assert ctl.submit(0.0, 0, 0) == 0
    # a submission 16+ ticks past the emit cursor is shed, not buffered
    assert ctl.submit(16 * 25.0, 0, 0) == -1
    assert ctl.shed_tasks == 1
    assert ctl.builder.pending_ticks <= 16
    # polling advances the cursor and the same timestamp is admitted
    ctl.poll(16 * 25.0)
    assert ctl.submit(16 * 25.0, 0, 0) >= 0
    snap = ctl.metrics_snapshot()
    assert snap["shed_tasks"] == 1
    assert snap["shed_policy"] == "reject"
    assert snap["max_pending_ticks"] == 16


def test_backpressure_degrade_advances_instead_of_shedding():
    spec = get("baseline", duration_ms=3000)
    ctl = FleetController(spec.models, "DEMS-A", n_edges=2,
                          window_ticks=8, max_pending_ticks=16,
                          shed_policy="degrade")
    assert ctl.submit(0.0, 0, 0) == 0
    # far-future submission force-steps windows instead of rejecting
    assert ctl.submit(40 * 25.0, 0, 0) >= 0
    assert ctl.shed_tasks == 0
    assert ctl.degrade_windows > 0
    assert ctl.tick > 0
    assert ctl.builder.pending_ticks <= 16


def test_backpressure_config_validated():
    spec = get("baseline", duration_ms=2000)
    with pytest.raises(ValueError, match="shed_policy"):
        FleetController(spec.models, "DEMS", n_edges=1,
                        shed_policy="panic")
    with pytest.raises(ValueError, match="max_pending_ticks"):
        FleetController(spec.models, "DEMS", n_edges=1,
                        window_ticks=8, max_pending_ticks=4)


def test_duplicate_task_ids_are_idempotent(tmp_path):
    spec = get("baseline", duration_ms=2000)
    path = os.path.join(tmp_path, "ck")
    ctl = FleetController(spec.models, "DEMS-A", n_edges=2,
                          window_ticks=8, checkpoint_path=path)
    assert ctl.submit(100.0, 0, 0, task_id=7) >= 0
    assert ctl.submit(100.0, 0, 0, task_id=7) == -1
    assert ctl.duplicate_events == 1
    with pytest.raises(ValueError, match="task_id"):
        ctl.submit(0.0, 0, 0, task_id=-3)
    ctl.poll(2000.0)
    ctl.checkpoint()
    # the dedupe ring survives kill/restore: a replayed duplicate from
    # before the crash is still recognized afterwards
    fresh = FleetController(spec.models, "DEMS-A", n_edges=2,
                            window_ticks=8, checkpoint_path=path)
    fresh.restore()
    assert fresh.submit(100.0, 0, 0, task_id=7) == -1
    assert fresh.duplicate_events == 1
    assert fresh.submit(150.0, 0, 0, task_id=8) >= 0
    assert fresh.metrics_snapshot()["duplicate_events"] == 1


def _telemetry_events(duration_ms: float, n_edges: int,
                      n_models: int) -> list:
    """(t_ms, edge, model, task_id) stream with same-cell collisions."""
    events, tid = [], 0
    t = 0
    while t < duration_ms:
        events.append((float(t), t % n_edges, (t // 40) % n_models, tid))
        tid += 1
        if t % 200 == 0:        # a second task in the same (tick, cell)
            events.append((float(t), t % n_edges, (t // 40) % n_models,
                           tid))
            tid += 1
        t += 40
    return events


def test_restore_under_duplicated_out_of_order_replay():
    # satellite 3: an at-least-once channel (duplicates + reordering,
    # repro.faults.perturb_telemetry) feeding a controller that polls
    # only at mission end must land in the bitwise-identical state as
    # the exactly-once in-order twin — task_id dedupe absorbs the
    # duplicates, and boolean-lane spill-forward commutes over order
    from repro.faults import TelemetryChaos
    from repro.faults.compile import perturb_telemetry

    spec = get("baseline", duration_ms=4000)
    m = len(spec.models)
    events = _telemetry_events(4000.0, 2, m)
    kw = dict(n_edges=2, window_ticks=8)

    a = FleetController(spec.models, "DEMS-A", **kw)
    for t, e, mi, tid in events:
        assert a.submit(t, e, mi, task_id=tid) >= 0
    a.poll(4000.0)
    a.close()

    chaos = TelemetryChaos(drop_p=0.0, dup_p=0.35, reorder_p=0.6,
                           max_delay_ms=300.0, seed=2)
    replay = perturb_telemetry(events, chaos)
    assert len(replay) > len(events)        # duplicates really delivered
    assert [ev[3] for ev in replay] != [ev[3] for ev in events]  # reordered
    b = FleetController(spec.models, "DEMS-A", **kw)
    for t, e, mi, tid in replay:
        b.submit(t, e, mi, task_id=tid)
    b.poll(4000.0)
    b.close()

    assert b.duplicate_events > 0
    assert _leaves_equal(a.state, b.state) == []
    assert b.summary() == a.summary()


def test_kill_restore_mid_crash_window_bitwise():
    # checkpoint taken *inside* an active EdgeCrash window, restore,
    # finish: bitwise-identical to the uninterrupted streamed run
    import dataclasses as dc
    import tempfile

    from repro.faults import EdgeCrash, FaultSpec

    spec = dc.replace(
        get("baseline", duration_ms=5000), name="crash-stream",
        faults=FaultSpec(crashes=(
            EdgeCrash(edge=0, start_ms=1500.0, end_ms=3500.0),)))
    sig = compile_fleet(spec)
    T = int(sig.times.shape[0])
    kw = dict(n_edges=spec.n_edges, window_ticks=16,
              cloud_slots=spec.cloud_concurrency)

    a = FleetController(spec.models, "DEMS-A", **kw)
    for lo in range(0, T, 16):
        a.step_signals(slice_signals(sig, lo, min(lo + 16, T)))

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck")
        b = FleetController(spec.models, "DEMS-A", checkpoint_path=path,
                            **kw)
        kill_tick = 80                       # inside the crash window
        assert np.asarray(sig.edge_up)[kill_tick, 0] == False  # noqa: E712
        for lo in range(0, kill_tick, 16):
            b.step_signals(slice_signals(sig, lo, lo + 16))
        b.checkpoint()
        del b

        c = FleetController(spec.models, "DEMS-A", checkpoint_path=path,
                            **kw)
        assert c.restore() == kill_tick
        for lo in range(kill_tick, T, 16):
            c.step_signals(slice_signals(sig, lo, min(lo + 16, T)))

    assert _leaves_equal(a.state, c.state) == []
    assert c.summary() == a.summary()
