"""Hypothesis property tests on system-level invariants of the simulator."""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without the [test] extra: vendored shim
    from _minihyp import given, settings, strategies as st  # noqa: F401

from repro.core.schedulers import ALL_POLICIES, make_policy
from repro.core.task import ModelProfile
from repro.sim.engine import Arrival, run_policy

profile_st = st.builds(
    lambda i, beta, dl, te, tc_mult, ke, kc: ModelProfile(
        name=f"M{i}", beta=float(beta), deadline=float(dl),
        t_edge=float(te), t_cloud=float(te * tc_mult),
        cost_edge=float(ke), cost_cloud=float(kc),
        qoe_beta=50.0, qoe_alpha=0.8, qoe_window=10_000.0),
    i=st.integers(0, 9), beta=st.integers(20, 300),
    dl=st.integers(300, 1500), te=st.integers(50, 800),
    tc_mult=st.floats(0.5, 3.0), ke=st.integers(1, 8),
    kc=st.integers(5, 320))


@st.composite
def workload_st(draw):
    n_models = draw(st.integers(1, 4))
    profiles = [draw(profile_st) for _ in range(n_models)]
    # distinct names
    profiles = [dataclasses.replace(p, name=f"M{i}")
                for i, p in enumerate(profiles)]
    n_drones = draw(st.integers(1, 3))
    arrivals = []
    for d in range(n_drones):
        for s in range(30):
            for p in profiles:
                arrivals.append(Arrival(time=s * 1000.0 + d * 137.0,
                                        model=p, drone=d))
    return arrivals


@settings(max_examples=25, deadline=None)
@given(workload_st(), st.sampled_from(["EDF-E+C", "DEMS", "GEMS", "SOTA1",
                                       "SOTA2", "CLD"]),
       st.integers(0, 5))
def test_simulator_invariants(arrivals, policy, seed):
    r = run_policy(make_policy(policy), arrivals, 30_000.0, seed=seed)
    total_gamma_e = 0.0
    for name, stt in r.per_model.items():
        m = next(a.model for a in arrivals if a.model.name == name)
        # conservation: every generated task reaches a terminal state
        done = (stt.edge_success + stt.edge_miss + stt.cloud_success
                + stt.cloud_miss + stt.dropped)
        assert done == stt.generated
        # per-model utility bounded by its best case / worst case
        best = stt.generated * max(m.gamma_edge, m.gamma_cloud, 0)
        worst = -stt.generated * max(m.cost_edge, m.cost_cloud)
        assert worst <= stt.qos_utility <= best + 1e-6
        # QoE identity
        assert stt.qoe_utility == pytest.approx(
            stt.windows_met * m.qoe_beta)
        assert stt.windows_met <= stt.windows_total
        total_gamma_e += stt.generated * m.gamma_edge
    # edge executor is a single synchronous stream (the final task may
    # straddle the horizon end, so allow its overhang)
    max_dur = max(a.model.t_edge for a in arrivals) * 1.1
    assert r.edge_utilization <= 1.0 + max_dur / 30_000.0


@settings(max_examples=15, deadline=None)
@given(workload_st(), st.integers(0, 3))
def test_negative_cloud_utility_never_executes_on_cloud(arrivals, seed):
    """Under DEMS, γ^C ≤ 0 tasks may be parked for stealing but must never
    be *executed* on the cloud (§5.3)."""
    r = run_policy(make_policy("DEMS"), arrivals, 30_000.0, seed=seed)
    for name, stt in r.per_model.items():
        m = next(a.model for a in arrivals if a.model.name == name)
        if m.gamma_cloud <= 0:
            assert stt.cloud_success == 0 and stt.cloud_miss == 0


@settings(max_examples=15, deadline=None)
@given(workload_st(), st.integers(0, 3))
def test_edge_only_never_touches_cloud(arrivals, seed):
    r = run_policy(make_policy("EDF"), arrivals, 30_000.0, seed=seed)
    for stt in r.per_model.values():
        assert stt.cloud_success == 0 and stt.cloud_miss == 0
        assert stt.stolen == 0 and stt.migrated == 0


@settings(max_examples=10, deadline=None)
@given(workload_st(), st.integers(0, 3))
def test_dems_dominates_edge_only_on_completion(arrivals, seed):
    """Adding a cloud under DEMS should never *reduce* on-time completions
    vs the pure-edge EDF baseline (same seed → same edge duration draws
    in distribution)."""
    edge = run_policy(make_policy("EDF"), arrivals, 30_000.0, seed=seed)
    dems = run_policy(make_policy("DEMS"), arrivals, 30_000.0, seed=seed)
    # allow slack: different RNG consumption order perturbs durations,
    # and DEMS may trade a few completions for utility
    assert dems.completed >= edge.completed * 0.85 - 5
