"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned architecture: instantiate a reduced variant of the same
family (2 layers, d_model ≤ 512, ≤ 4 experts), run one forward/train step,
assert output shapes and absence of NaNs — plus prefill→decode consistency
against the full-sequence forward pass (the strongest correctness check we
can run without hardware).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.models.model import Model

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, rng, batch=2, seq=32):
    tokens = jax.random.randint(rng, (batch, seq), 0, cfg.vocab)
    b = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(rng, (batch, cfg.n_frames,
                                              cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(rng, (batch, cfg.n_image_tokens,
                                               cfg.d_model)) * 0.02
    return b


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name, rng):
    cfg = reduced(ARCHS[name])
    model = Model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{name}: NaN/inf in logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_no_nans(name, rng):
    cfg = reduced(ARCHS[name])
    model = Model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, rng)

    @jax.jit
    def step(p):
        loss, grads = jax.value_and_grad(model.loss)(p, batch)
        new = jax.tree.map(lambda a, g: a - 1e-3 * g, p, grads)
        return loss, new

    loss, new_params = step(params)
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss {loss}"
    assert float(loss) > 0
    leaves = jax.tree.leaves(new_params)
    assert all(bool(jnp.isfinite(a).all()) for a in leaves), \
        f"{name}: non-finite params after one step"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_forward(name, rng):
    """Teacher-forcing equivalence: prefill(S−k) + k decode steps must give
    the same last-token logits as a full forward pass."""
    cfg = reduced(ARCHS[name])
    model = Model(cfg)
    params = model.init(rng)
    seq, k = 24, 4
    batch = _batch(cfg, rng, batch=2, seq=seq)
    tokens = batch["tokens"]

    full_logits, _ = jax.jit(model.forward)(params, batch)

    pre_batch = dict(batch, tokens=tokens[:, : seq - k])
    max_seq = seq + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_seq))(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, seq - k - 1]),
        rtol=2e-2, atol=2e-2, err_msg=f"{name}: prefill last logits")

    offset = cfg.n_image_tokens if cfg.family == "vlm" else 0
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    for i in range(seq - k, seq):
        tok = tokens[:, i: i + 1]
        logits, cache = step(params, cache, tok, jnp.asarray(i + offset))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, i]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{name}: decode step at position {i}")


def test_sliding_window_cache_is_window_sized():
    cfg = reduced(ARCHS["qwen2-72b"])
    assert cfg.sliding_window == 16
    model = Model(cfg)
    cache = model.init_cache(batch_size=1, max_seq=4096)
    assert cache["k"].shape[2] == 16      # ring buffer, not 4096


def test_param_counts_in_expected_range():
    # sanity: full-config parameter counts are in the advertised ballpark
    assert 250e9 < ARCHS["grok-1-314b"].param_count() < 400e9
    assert 20e9 < ARCHS["qwen3-moe-30b-a3b"].param_count() < 40e9
    assert 60e9 < ARCHS["qwen2-72b"].param_count() < 90e9
    assert 250e9 < ARCHS["nemotron-4-340b"].param_count() < 450e9
    assert 2e9 < ARCHS["granite-3-2b"].param_count() < 4e9
    assert 1e9 < ARCHS["xlstm-1.3b"].param_count() < 2.5e9
    # MoE active params well below total
    g = ARCHS["grok-1-314b"]
    assert g.active_param_count() < 0.4 * g.param_count()


def test_param_specs_match_param_structure(rng):
    for name in ("granite-3-2b", "qwen3-moe-30b-a3b", "zamba2-7b"):
        cfg = reduced(ARCHS[name])
        model = Model(cfg)
        params = model.init(rng)
        specs = model.param_specs()
        pt = jax.tree.structure(params)
        st = jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, tuple))
        assert pt == st, f"{name}: specs/params structure mismatch"


def test_shardmap_flash_decode_matches_baseline(rng):
    """§Perf optimization: the shard_map flash-decode must be numerically
    identical to the GSPMD baseline path (1-device mesh here; the dry-run
    exercises 256/512 devices)."""
    import dataclasses
    import jax
    from repro.launch.mesh import make_host_mesh
    from repro.launch.sharding import sharding_rules

    cfg = reduced(ARCHS["qwen2-72b"])
    model_base = Model(cfg)
    model_opt = Model(dataclasses.replace(cfg, opt_decode=True))
    params = model_base.init(rng)
    tokens = jax.random.randint(rng, (2, 12), 0, cfg.vocab)
    _, cache = model_base.prefill(params, {"tokens": tokens}, 32)

    tok = tokens[:, -1:]
    base_logits, base_cache = model_base.decode_step(
        params, cache, tok, jnp.asarray(12))
    mesh = make_host_mesh()
    with sharding_rules(mesh):
        opt_logits, opt_cache = jax.jit(model_opt.decode_step)(
            params, cache, tok, jnp.asarray(12))
    np.testing.assert_allclose(np.asarray(base_logits),
                               np.asarray(opt_logits), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(base_cache["k"]),
                               np.asarray(opt_cache["k"]), rtol=1e-5,
                               atol=1e-5)


def test_split_expert_moe_matches_unsplit(rng):
    """§Perf: split-expert layout (E·s, D, Fe/s) must be numerically
    identical to the plain (E, D, Fe) expert GEMMs."""
    import dataclasses
    from repro.models import moe as MOE

    cfg = reduced(ARCHS["grok-1-314b"])
    cfg2 = dataclasses.replace(cfg, expert_split=2)
    model = Model(cfg)
    params = model.init(rng)
    x = jax.random.normal(rng, (2, 16, cfg.d_model)) * 0.3
    blk = jax.tree.map(lambda a: a[0], params["blocks"])
    y1, aux1 = MOE.moe_mlp(blk, cfg, x)

    e, d, fe, s = cfg.n_experts, cfg.d_model, cfg.d_ff_expert, 2
    blk2 = dict(blk)
    for key in ("we_i",) if cfg.act != "silu" else ("we_g", "we_u"):
        blk2[key] = blk[key].reshape(e, d, s, fe // s).transpose(
            0, 2, 1, 3).reshape(e * s, d, fe // s)
    blk2["we_d"] = blk[key.replace(key, "we_d")]
    blk2["we_d"] = blk["we_d"].reshape(e, s, fe // s, d).reshape(
        e * s, fe // s, d)
    y2, aux2 = MOE.moe_mlp(blk2, cfg2, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
