"""Fleet simulator vs the discrete-event oracle, plus SPMD scaling checks."""
import jax
import numpy as np
import pytest

from repro.core.schedulers import make_policy
from repro.core.task import PASSIVE, TABLE1
from repro.sim.engine import run_policy
from repro.sim.fleet_jax import FleetPolicy, Profiles, simulate_fleet
from repro.sim.network import CloudLatencyModel, EdgeLatencyModel, trapezium
from repro.sim.workloads import task_stream

MODELS = [TABLE1[n] for n in PASSIVE]


def _engine_result(policy, duration=120_000.0, seed=0, theta_fn=None):
    em = EdgeLatencyModel(mean_frac=0.62, sd_frac=0.0, lo_frac=0.62,
                          hi_frac=0.62)
    cm = CloudLatencyModel(median_frac=0.80, sigma=1e-6, cold_start_p=0.0,
                           **({"latency_at": theta_fn} if theta_fn else {}))
    arr = task_stream(MODELS, n_drones=3, duration_ms=duration, seed=seed)
    return run_policy(make_policy(policy), arr, duration, seed=seed,
                      edge_model=em, cloud_model=cm, cloud_concurrency=512)


@pytest.mark.parametrize("policy", ["EDF-E+C", "DEMS", "GEMS"])
def test_fleet_matches_event_engine_approximately(policy):
    """Tick-based SPMD sim tracks the event-driven oracle within 10 %."""
    duration = 120_000.0
    oracle = _engine_result(policy, duration)
    final = simulate_fleet(MODELS, policy, n_edges=1, drones_per_edge=3,
                           duration_ms=duration, dt=25.0,
                           edge_frac=0.62, cloud_frac=0.80, seed=0)
    got = float(np.asarray(final.n_success).sum())
    want = oracle.completed
    assert abs(got - want) / want < 0.10, (got, want)
    got_u = float(np.asarray(final.qos_utility).sum())
    assert abs(got_u - oracle.qos_utility) / abs(oracle.qos_utility) < 0.15


def test_fleet_dems_a_matches_oracle_under_trapezium():
    """§5.4 adaptation in the vmapped tick loop tracks the oracle's
    DEMS-A under the §8.5 trapezium θ trace (single edge)."""
    duration = 300_000.0
    oracle = _engine_result("DEMS-A", duration, theta_fn=trapezium())
    final = simulate_fleet(MODELS, "DEMS-A", n_edges=1, drones_per_edge=3,
                           duration_ms=duration, dt=25.0, edge_frac=0.62,
                           cloud_frac=0.80, theta_fn=trapezium(), seed=0)
    got = float(np.asarray(final.n_success).sum())
    want = oracle.completed
    assert abs(got - want) / want < 0.10, (got, want)
    got_u = float(np.asarray(final.qos_utility).sum())
    assert abs(got_u - oracle.qos_utility) / abs(oracle.qos_utility) < 0.15
    # the estimator must have reacted: some model's t̂ ends above static
    cur = np.asarray(final.adapt.current)
    static = np.asarray([m.t_cloud for m in MODELS])
    assert (cur > static + 1.0).any(), cur


def test_fleet_dems_a_beats_dems_under_variability():
    """Paper Fig. 11: adaptation pays off on QoS when θ(t) swings."""
    kw = dict(n_edges=1, drones_per_edge=3, duration_ms=300_000.0,
              theta_fn=trapezium(), seed=0)
    adpt = simulate_fleet(MODELS, "DEMS-A", **kw)
    base = simulate_fleet(MODELS, "DEMS", **kw)
    assert float(np.asarray(adpt.qos_utility).sum()) >= \
        float(np.asarray(base.qos_utility).sum())


def test_fleet_dems_steals_and_beats_e_plus_c():
    kw = dict(n_edges=2, drones_per_edge=3, duration_ms=90_000.0)
    dems = simulate_fleet(MODELS, "DEMS", **kw)
    epc = simulate_fleet(MODELS, "EDF-E+C", **kw)
    assert np.asarray(dems.n_stolen).sum() > 0
    assert np.asarray(dems.qos_utility).sum() >= \
        np.asarray(epc.qos_utility).sum()


def test_fleet_scales_edges_linearly():
    """Weak scaling (paper §8.6): per-edge results independent of fleet size."""
    a = simulate_fleet(MODELS, "DEMS", n_edges=1, duration_ms=60_000.0,
                       seed=1)
    b = simulate_fleet(MODELS, "DEMS", n_edges=8, duration_ms=60_000.0,
                       seed=1)
    per_edge_a = float(np.asarray(a.n_success).sum())
    per_edge_b = float(np.asarray(b.n_success).sum()) / 8
    assert abs(per_edge_b - per_edge_a) / per_edge_a < 0.15


def test_fleet_gems_accrues_qoe():
    import dataclasses
    models = [dataclasses.replace(m, qoe_alpha=0.5, qoe_beta=100.0,
                                  qoe_window=10_000.0) for m in MODELS]
    final = simulate_fleet(models, "GEMS", n_edges=1,
                           duration_ms=60_000.0)
    assert float(np.asarray(final.qoe_utility).sum()) > 0
    assert int(np.asarray(final.windows_met).sum()) > 0


def test_fleet_task_conservation():
    final = simulate_fleet(MODELS, "DEMS", n_edges=2, drones_per_edge=2,
                           duration_ms=60_000.0)
    done = (np.asarray(final.n_success).sum() + np.asarray(final.n_miss).sum()
            + np.asarray(final.n_drop).sum())
    generated = 2 * 2 * 60 * len(MODELS)
    # a handful of tasks may still be queued when the horizon ends
    assert generated * 0.97 <= done <= generated


def test_fleet_sharded_over_mesh_axis():
    mesh = jax.make_mesh((jax.device_count(),), ("fleet",))
    final = simulate_fleet(MODELS, "DEMS", n_edges=4,
                           duration_ms=30_000.0, mesh=mesh)
    assert np.asarray(final.n_success).sum() > 0
