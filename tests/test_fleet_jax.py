"""Fleet simulator vs the discrete-event oracle, plus SPMD scaling checks."""
import jax
import numpy as np
import pytest

from repro.core.schedulers import make_policy
from repro.core.task import PASSIVE, TABLE1
from repro.sim.engine import run_policy
from repro.sim.fleet_jax import FleetPolicy, Profiles, simulate_fleet
from repro.sim.network import CloudLatencyModel, EdgeLatencyModel, trapezium
from repro.sim.workloads import task_stream

MODELS = [TABLE1[n] for n in PASSIVE]


def _engine_result(policy, duration=120_000.0, seed=0, theta_fn=None):
    em = EdgeLatencyModel(mean_frac=0.62, sd_frac=0.0, lo_frac=0.62,
                          hi_frac=0.62)
    cm = CloudLatencyModel(median_frac=0.80, sigma=1e-6, cold_start_p=0.0,
                           **({"latency_at": theta_fn} if theta_fn else {}))
    arr = task_stream(MODELS, n_drones=3, duration_ms=duration, seed=seed)
    return run_policy(make_policy(policy), arr, duration, seed=seed,
                      edge_model=em, cloud_model=cm, cloud_concurrency=512)


@pytest.mark.parametrize("policy", ["EDF-E+C", "DEMS", "GEMS"])
def test_fleet_matches_event_engine_approximately(policy):
    """Tick-based SPMD sim tracks the event-driven oracle within 10 %."""
    duration = 120_000.0
    oracle = _engine_result(policy, duration)
    final = simulate_fleet(MODELS, policy, n_edges=1, drones_per_edge=3,
                           duration_ms=duration, dt=25.0, cloud_slots=512,
                           edge_frac=0.62, cloud_frac=0.80, seed=0)
    got = float(np.asarray(final.n_success).sum())
    want = oracle.completed
    assert abs(got - want) / want < 0.10, (got, want)
    got_u = float(np.asarray(final.qos_utility).sum())
    assert abs(got_u - oracle.qos_utility) / abs(oracle.qos_utility) < 0.15


def test_fleet_dems_a_matches_oracle_under_trapezium():
    """§5.4 adaptation in the vmapped tick loop tracks the oracle's
    DEMS-A under the §8.5 trapezium θ trace (single edge)."""
    duration = 300_000.0
    oracle = _engine_result("DEMS-A", duration, theta_fn=trapezium())
    final = simulate_fleet(MODELS, "DEMS-A", n_edges=1, drones_per_edge=3,
                           duration_ms=duration, dt=25.0, cloud_slots=512,
                           edge_frac=0.62, cloud_frac=0.80,
                           theta_fn=trapezium(), seed=0)
    got = float(np.asarray(final.n_success).sum())
    want = oracle.completed
    assert abs(got - want) / want < 0.10, (got, want)
    got_u = float(np.asarray(final.qos_utility).sum())
    assert abs(got_u - oracle.qos_utility) / abs(oracle.qos_utility) < 0.15
    # the estimator must have reacted: some model's t̂ ends above static
    cur = np.asarray(final.adapt.current)
    static = np.asarray([m.t_cloud for m in MODELS])
    assert (cur > static + 1.0).any(), cur


def _scenario_agreement(scenario_name, policy="DEMS",
                        duration_ms=120_000.0):
    """Deterministic oracle vs fleet on a registry scenario; relative
    errors on completed tasks and QoS utility."""
    from repro.scenarios import (fleet_summary, get, run_scenario_fleet,
                                 run_scenario_oracle)

    spec = get(scenario_name, duration_ms=duration_ms)
    em = EdgeLatencyModel(mean_frac=0.62, sd_frac=0.0, lo_frac=0.62,
                          hi_frac=0.62)
    oracle = run_scenario_oracle(
        spec, policy, edge_model=em,
        cloud_model_overrides=dict(median_frac=0.80, sigma=1e-6,
                                   cold_start_p=0.0)).merged
    fleet = fleet_summary(run_scenario_fleet(spec, policy))
    d_done = abs(fleet["completed"] - oracle.completed) / oracle.completed
    d_qos = abs(fleet["qos_utility"] - oracle.qos_utility) / \
        abs(oracle.qos_utility)
    return oracle, fleet, d_done, d_qos


@pytest.mark.parametrize("policy", ["HPF", "CLD", "SJF-E+C", "SOTA1",
                                    "SOTA2", "GEMS-B"])
def test_fleet_matches_oracle_across_policy_matrix(policy):
    """Every §8.2 baseline (and the beyond-paper GEMS-B) agrees with the
    event-driven oracle within 10 % on a bursty registry scenario — the
    coverage that lets the one-program fleet sweep reproduce the paper's
    baseline comparison (Fig. 8) without falling back to the oracle."""
    oracle, fleet, d_done, d_qos = _scenario_agreement(
        "rush-hour", policy, duration_ms=90_000.0)
    assert d_done < 0.10, (policy, fleet["completed"], oracle.completed)
    assert d_qos < 0.10, (policy, fleet["qos_utility"], oracle.qos_utility)


def test_fleet_sota1_extension_is_scheduling_only():
    """SOTA1's 10 % deadline buffer buys insertions, not successes: the
    fleet must judge success at the *absolute* deadline, so SOTA1 can
    never out-complete the same mission where every completion counted
    (both sims agree — see the oracle's ``Task.sched_deadline``)."""
    from repro.scenarios import fleet_summary, get, run_scenario_fleet

    spec = get("rush-hour", duration_ms=60_000.0)
    sota1 = fleet_summary(run_scenario_fleet(spec, "SOTA1"))
    # settled tasks conserve: successes counted at abs deadline + misses
    # + drops add up the same as EDF-E+C (same arrivals, no stealing)
    epc = fleet_summary(run_scenario_fleet(spec, "EDF-E+C"))
    tot_sota1 = sota1["completed"] + sota1["missed"] + sota1["dropped"]
    tot_epc = epc["completed"] + epc["missed"] + epc["dropped"]
    assert abs(tot_sota1 - tot_epc) <= 0.02 * tot_epc
    # the buffer admits more edge inserts than plain EDF-E+C feasibility
    assert tot_sota1 > 0 and sota1["completed"] > 0


def test_fleet_cld_drops_negative_cloud_utility_tasks():
    """CLD routes everything cloud-ward and drops γ^C≤0 models (BP) —
    mirroring the oracle's admission check exactly."""
    final = simulate_fleet(MODELS, "CLD", n_edges=1, duration_ms=30_000.0,
                           cloud_slots=512)
    by_model = np.asarray(final.n_success).sum(0)
    bp = next(i for i, m in enumerate(MODELS) if m.gamma_cloud <= 0)
    assert by_model[bp] == 0                       # BP never completes
    assert np.asarray(final.n_drop).sum(0)[bp] > 0
    assert np.asarray(final.n_edge_exec).sum() == 0  # edge never used


def test_fleet_matches_oracle_under_saturated_cloud_pool():
    """cloud-crunch: 2 FaaS slots per edge + 4× burst — the fleet's
    finite-pool queue-wait must track the oracle's slot contention, not
    the old elastic cloud (which over-reported utility by >30 %)."""
    oracle, fleet, d_done, d_qos = _scenario_agreement("cloud-crunch")
    n_dropped = sum(s.dropped for s in oracle.per_model.values())
    assert n_dropped > 0.2 * oracle.generated        # pool really saturates
    assert d_done < 0.10, (fleet["completed"], oracle.completed)
    assert d_qos < 0.10, (fleet["qos_utility"], oracle.qos_utility)


def test_fleet_matches_oracle_under_bandwidth_fade():
    """bw-fade: deep cellular fade — the dense ``bw`` signal must apply
    the same signed transfer penalty as the oracle's shaped_delta."""
    oracle, fleet, d_done, d_qos = _scenario_agreement("bw-fade")
    assert d_done < 0.10, (fleet["completed"], oracle.completed)
    assert d_qos < 0.10, (fleet["qos_utility"], oracle.qos_utility)


def test_finite_pool_and_fade_degrade_fleet_utility():
    """Small pools and fades must hurt: the congestion scenarios exist to
    break the elastic-cloud optimism, so their fleet utility is strictly
    below the same mission with an ample pool / nominal bandwidth."""
    import dataclasses as dc

    from repro.scenarios import fleet_summary, get, run_scenario_fleet

    crunch = get("cloud-crunch", duration_ms=60_000.0)
    ample = dc.replace(crunch, cloud_concurrency=512)
    s_tight = fleet_summary(run_scenario_fleet(crunch, "DEMS"))
    s_ample = fleet_summary(run_scenario_fleet(ample, "DEMS"))
    assert s_tight["qos_utility"] < s_ample["qos_utility"]
    assert s_tight["completed"] < s_ample["completed"]

    fade = get("bw-fade", duration_ms=60_000.0)
    clear = dc.replace(fade, bandwidth=None)
    f_fade = fleet_summary(run_scenario_fleet(fade, "DEMS"))
    f_clear = fleet_summary(run_scenario_fleet(clear, "DEMS"))
    assert f_fade["qos_utility"] < f_clear["qos_utility"]


def test_fleet_dems_a_beats_dems_under_variability():
    """Paper Fig. 11: adaptation pays off on QoS when θ(t) swings."""
    kw = dict(n_edges=1, drones_per_edge=3, duration_ms=300_000.0,
              theta_fn=trapezium(), seed=0)
    adpt = simulate_fleet(MODELS, "DEMS-A", **kw)
    base = simulate_fleet(MODELS, "DEMS", **kw)
    assert float(np.asarray(adpt.qos_utility).sum()) >= \
        float(np.asarray(base.qos_utility).sum())


def test_fleet_dems_steals_and_beats_e_plus_c():
    kw = dict(n_edges=2, drones_per_edge=3, duration_ms=90_000.0)
    dems = simulate_fleet(MODELS, "DEMS", **kw)
    epc = simulate_fleet(MODELS, "EDF-E+C", **kw)
    assert np.asarray(dems.n_stolen).sum() > 0
    assert np.asarray(dems.qos_utility).sum() >= \
        np.asarray(epc.qos_utility).sum()


def test_fleet_scales_edges_linearly():
    """Weak scaling (paper §8.6): per-edge results independent of fleet size."""
    a = simulate_fleet(MODELS, "DEMS", n_edges=1, duration_ms=60_000.0,
                       seed=1)
    b = simulate_fleet(MODELS, "DEMS", n_edges=8, duration_ms=60_000.0,
                       seed=1)
    per_edge_a = float(np.asarray(a.n_success).sum())
    per_edge_b = float(np.asarray(b.n_success).sum()) / 8
    assert abs(per_edge_b - per_edge_a) / per_edge_a < 0.15


def test_fleet_gems_accrues_qoe():
    import dataclasses
    models = [dataclasses.replace(m, qoe_alpha=0.5, qoe_beta=100.0,
                                  qoe_window=10_000.0) for m in MODELS]
    final = simulate_fleet(models, "GEMS", n_edges=1,
                           duration_ms=60_000.0)
    assert float(np.asarray(final.qoe_utility).sum()) > 0
    assert int(np.asarray(final.windows_met).sum()) > 0


def test_fleet_gems_b_restrains_flood_once_window_is_lost():
    """At α=1.0 Alg. 1's rate check is absorbing: one failure loses the
    window for good, yet GEMS keeps flooding the cloud.  GEMS-B's
    winnability gate (per-window ``prev_lam`` arrival forecast) must keep
    strictly more of the still-salvageable work on the edge."""
    import dataclasses
    models = [dataclasses.replace(m, qoe_alpha=1.0, qoe_beta=100.0,
                                  qoe_window=10_000.0) for m in MODELS]
    kw = dict(n_edges=1, drones_per_edge=8, duration_ms=60_000.0,
              cloud_slots=4)
    gems = simulate_fleet(models, "GEMS", **kw)
    gems_b = simulate_fleet(models, "GEMS-B", **kw)
    edge_g = int(np.asarray(gems.n_edge_exec).sum())
    edge_b = int(np.asarray(gems_b.n_edge_exec).sum())
    assert edge_b > edge_g, (edge_b, edge_g)


def test_fleet_task_conservation():
    final = simulate_fleet(MODELS, "DEMS", n_edges=2, drones_per_edge=2,
                           duration_ms=60_000.0)
    done = (np.asarray(final.n_success).sum() + np.asarray(final.n_miss).sum()
            + np.asarray(final.n_drop).sum())
    generated = 2 * 2 * 60 * len(MODELS)
    # a handful of tasks may still be queued when the horizon ends
    assert generated * 0.97 <= done <= generated


def test_fleet_sharded_over_mesh_axis():
    mesh = jax.make_mesh((jax.device_count(),), ("fleet",))
    final = simulate_fleet(MODELS, "DEMS", n_edges=4,
                           duration_ms=30_000.0, mesh=mesh)
    assert np.asarray(final.n_success).sum() > 0
