"""Regenerate the fleet_summary golden file after an intentional change.

    PYTHONPATH=src python tests/golden/regen_fleet_summaries.py
    PYTHONPATH=src python tests/golden/regen_fleet_summaries.py --check

``--check`` recomputes every summary and fails (exit 1) if the checked-in
golden file has drifted beyond a small tolerance, without rewriting it —
the CI staleness gate.  The tolerance (rel 5e-3, abs 1.5) forgives
last-ulp float32-reduction differences across JAX versions / BLAS /
platforms (which can flip a borderline task, shifting a count by one)
while still catching any real behavior change a contributor forgot to
regenerate for; tests/test_fleet_batch.py compares at a looser 5 % for
the same reason.

Keep the duration / seed / policies in sync with tests/test_fleet_batch.py.
"""
import json
import pathlib
import sys

from repro.scenarios import fleet_summary, get, names, run_scenario_fleet

GOLDEN_DURATION_MS = 45_000.0
POLICIES = ("DEMS", "GEMS-COOP", "SJF-E+C", "GEMS-B")
REL_TOL = 5e-3
ABS_TOL = 1.5


def _compute() -> dict:
    out = {}
    for sc in names():
        out[sc] = {}
        for pol in POLICIES:
            spec = get(sc, duration_ms=GOLDEN_DURATION_MS, seed=0)
            out[sc][pol] = fleet_summary(run_scenario_fleet(spec, pol,
                                                            dt=25.0))
            print(sc, pol, out[sc][pol]["completed"], flush=True)
    return out


def _drift(golden: dict, fresh: dict, path: str = "") -> list[str]:
    bad = []
    keys = sorted(set(golden) | set(fresh))
    for k in keys:
        at = f"{path}/{k}"
        if k not in golden or k not in fresh:
            bad.append(f"{at}: only in {'fresh' if k in fresh else 'golden'}")
        elif isinstance(golden[k], dict):
            bad.extend(_drift(golden[k], fresh[k], at))
        else:
            g, f = float(golden[k]), float(fresh[k])
            if abs(g - f) > max(ABS_TOL, REL_TOL * abs(g)):
                bad.append(f"{at}: golden {golden[k]} vs fresh {fresh[k]}")
    return bad


def main() -> None:
    path = pathlib.Path(__file__).parent / "fleet_summaries.json"
    fresh = _compute()
    if "--check" in sys.argv[1:]:
        golden = json.loads(path.read_text())
        bad = _drift(golden, fresh)
        if bad:
            print(f"golden file is stale ({len(bad)} drifted values) — "
                  "rerun this script without --check and commit:")
            print("\n".join(bad))
            sys.exit(1)
        print("golden file is fresh:", path)
        return
    path.write_text(json.dumps(fresh, indent=1, sort_keys=True) + "\n")
    print("wrote", path)


if __name__ == "__main__":
    main()
