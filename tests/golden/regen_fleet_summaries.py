"""Regenerate the fleet_summary golden file after an intentional change.

    PYTHONPATH=src python tests/golden/regen_fleet_summaries.py

Keep the duration / seed / policies in sync with tests/test_fleet_batch.py.
"""
import json
import pathlib

from repro.scenarios import fleet_summary, get, names, run_scenario_fleet

GOLDEN_DURATION_MS = 45_000.0
POLICIES = ("DEMS", "GEMS-COOP")


def main() -> None:
    out = {}
    for sc in names():
        out[sc] = {}
        for pol in POLICIES:
            spec = get(sc, duration_ms=GOLDEN_DURATION_MS, seed=0)
            out[sc][pol] = fleet_summary(run_scenario_fleet(spec, pol,
                                                            dt=25.0))
            print(sc, pol, out[sc][pol]["completed"], flush=True)
    path = pathlib.Path(__file__).parent / "fleet_summaries.json"
    path.write_text(json.dumps(out, indent=1, sort_keys=True) + "\n")
    print("wrote", path)


if __name__ == "__main__":
    main()
