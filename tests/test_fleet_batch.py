"""Batched one-jit sweeps + adaptive policy coverage + golden metrics.

1. ``FleetPolicy.from_name`` accepts the full oracle-mirroring name set
   (including ``DEMS-A`` / ``GEMS-A`` and ``-COOP`` variants) and raises
   a ``ValueError`` listing supported names on typos;
2. ``run_fleet_batch`` (one vmapped jit over stacked replica signals)
   reproduces per-run ``run_fleet`` metrics exactly, seed by seed;
3. a golden-metrics file locks ``fleet_summary`` for every registry
   scenario × {DEMS, GEMS-COOP} at a fixed seed, with loose tolerances,
   so refactors of the tick loop can't silently shift results.

Regenerate the golden file after an *intentional* modeling change:

    PYTHONPATH=src python tests/golden/regen_fleet_summaries.py
"""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.scenarios import (compile_fleet, compile_fleet_batch,
                             fleet_summary, fleet_summary_batch, get, names,
                             run_scenario_fleet, run_scenario_fleet_batch)
from repro.sim.fleet_jax import (FleetPolicy, run_fleet, run_fleet_batch,
                                 stack_signals)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "fleet_summaries.json"
GOLDEN_DURATION_MS = 45_000.0
GOLDEN_POLICIES = ("DEMS", "GEMS-COOP", "SJF-E+C", "GEMS-B")


# ---------------------------------------------------------------------------
# (1) policy name registry
# ---------------------------------------------------------------------------

def test_from_name_unknown_policy_raises_value_error():
    with pytest.raises(ValueError, match="DEMS-A"):
        FleetPolicy.from_name("DEMZ")
    with pytest.raises(ValueError, match="choose from"):
        FleetPolicy.from_name("GEMS-A-KOOP")


@pytest.mark.parametrize("name,adaptive,gems,coop", [
    ("DEMS-A", True, False, False),
    ("GEMS-A", True, True, False),
    ("DEMS-A-COOP", True, False, True),
    ("GEMS-A-COOP", True, True, True),
    ("DEMS", False, False, False),
])
def test_from_name_adaptive_variants(name, adaptive, gems, coop):
    pol = FleetPolicy.from_name(name)
    assert pol.adaptive is adaptive
    assert pol.gems is gems
    assert pol.cooperation is coop
    assert pol.migration and pol.stealing


def test_gems_a_coop_runs_end_to_end():
    spec = get("hetero-edges", duration_ms=30_000.0)
    s = fleet_summary(run_scenario_fleet(spec, "GEMS-A-COOP"))
    assert s["completed"] > 0


def test_from_name_covers_full_oracle_registry():
    """Every oracle policy (plus its -COOP variant) resolves to a
    FleetPolicy whose flags mirror core.schedulers._POLICIES — the fleet
    coverage matrix has no more `—` cells."""
    from repro.core.schedulers import ALL_POLICIES, make_policy

    for name in ALL_POLICIES:
        oracle = make_policy(name)
        for fleet_name in (name, name + "-COOP"):
            pol = FleetPolicy.from_name(fleet_name)
            for flag in ("migration", "stealing", "gems", "adaptive",
                         "use_cloud", "use_edge", "edge_feasibility_check",
                         "edge_priority", "cloud_accepts_negative",
                         "sota1", "sota2", "gems_budget"):
                got, want = getattr(pol, flag), getattr(oracle, flag)
                assert got == want, (fleet_name, flag, got, want)
            assert pol.cooperation is fleet_name.endswith("-COOP")


# ---------------------------------------------------------------------------
# (2) one-jit batched sweep ≡ looped run_fleet
# ---------------------------------------------------------------------------

def test_run_fleet_batch_matches_looped_run_fleet_exactly():
    spec = get("baseline", duration_ms=30_000.0)
    seeds = (0, 1, 2)
    signals = [compile_fleet(sp) for sp in spec.reseeded(seeds)]
    batch = run_fleet_batch(spec.models, "DEMS-A", stack_signals(signals))
    for r, sig in enumerate(signals):
        single = run_fleet(spec.models, "DEMS-A", sig)
        replica = jax.tree.map(lambda a: a[r], batch)
        for got, want in zip(jax.tree.leaves(replica),
                             jax.tree.leaves(single)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_run_scenario_fleet_batch_summaries_match_per_seed_runs():
    spec = get("baseline", duration_ms=30_000.0)
    seeds = (3, 4)
    batch = run_scenario_fleet_batch(spec, "DEMS", seeds)
    summaries = fleet_summary_batch(batch)
    assert len(summaries) == len(seeds)
    for seed, got in zip(seeds, summaries):
        want = fleet_summary(run_scenario_fleet(
            get("baseline", duration_ms=30_000.0, seed=seed), "DEMS"))
        assert got == want


def test_compile_fleet_batch_stacks_replica_axis():
    spec = get("baseline", duration_ms=10_000.0)
    sig = compile_fleet_batch(spec, (0, 1, 2))
    assert sig.arrive.shape[0] == 3
    assert sig.arrive.shape[1:] == compile_fleet(spec).arrive.shape
    # different seeds → different arrival patterns
    a = np.asarray(sig.arrive)
    assert not np.array_equal(a[0], a[1])


# ---------------------------------------------------------------------------
# (3) golden metrics: registry × {DEMS, GEMS-COOP} at seed 0
# ---------------------------------------------------------------------------

def _assert_close(scenario, policy, key, got, want):
    if key == "completion_rate":
        tol = 0.02
    else:
        tol = max(3.0, 0.05 * abs(want))
    assert abs(got - want) <= tol, (
        f"{scenario}/{policy}/{key}: got {got}, golden {want} (±{tol:.3g}) "
        f"— if the modeling change is intentional, regenerate "
        f"tests/golden/fleet_summaries.json")


@pytest.mark.parametrize("scenario", sorted(names()))
def test_golden_fleet_summaries(scenario):
    golden = json.loads(GOLDEN.read_text())
    assert scenario in golden, "regenerate the golden file for new scenarios"
    for policy in GOLDEN_POLICIES:
        spec = get(scenario, duration_ms=GOLDEN_DURATION_MS, seed=0)
        got = fleet_summary(run_scenario_fleet(spec, policy, dt=25.0))
        for key, want in golden[scenario][policy].items():
            _assert_close(scenario, policy, key, got[key], want)
