"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,hd", [
    (1, 4, 4, 128, 64),      # MHA
    (2, 8, 2, 256, 64),      # GQA 4:1
    (1, 4, 1, 128, 128),     # MQA
])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_matches_ref(b, h, kv, s, hd, dtype, window):
    rng = jax.random.PRNGKey(hash((b, h, s, window)) % 2**31)
    kq, kk, kv_ = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (b, h, s, hd), dtype)
    k = jax.random.normal(kk, (b, kv, s, hd), dtype)
    v = jax.random.normal(kv_, (b, kv, s, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    want = ref.ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_non_causal():
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (1, 2, 128, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 64))
    got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    want = ref.ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_odd_block_shapes():
    # block sizes that do not divide into a square grid (s=256, bq=128, bk=64)
    rng = jax.random.PRNGKey(3)
    q = jax.random.normal(rng, (1, 2, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 256, 64))
    got = ops.flash_attention(q, k, v, block_q=128, block_k=64)
    want = ref.ref_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,w,hd", [
    (2, 4, 4, 512, 64),
    (3, 8, 2, 1024, 64),
    (1, 4, 1, 256, 128),
])
def test_decode_attention_matches_ref(b, h, kv, w, hd, dtype):
    rng = jax.random.PRNGKey(hash((b, h, w)) % 2**31)
    kq, kk, kv_, kl = jax.random.split(rng, 4)
    q = jax.random.normal(kq, (b, h, hd), dtype)
    k = jax.random.normal(kk, (b, kv, w, hd), dtype)
    v = jax.random.normal(kv_, (b, kv, w, hd), dtype)
    lengths = jax.random.randint(kl, (b,), 1, w + 1)
    got = ops.decode_attention(q, k, v, lengths, block_s=128)
    want = ref.ref_decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_length_one():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 64))
    lengths = jnp.array([1])
    got = ops.decode_attention(q, k, v, lengths, block_s=128)
    want = ref.ref_decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,s,p,n,chunk", [
    (4, 256, 64, 64, 128),
    (2, 128, 32, 16, 64),
    (8, 512, 64, 64, 128),
])
def test_ssm_scan_matches_ref(g, s, p, n, chunk):
    rng = jax.random.PRNGKey(hash((g, s, p, n)) % 2**31)
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (g, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (g, s)))
    a = -jnp.exp(jax.random.normal(ks[2], (g,)) * 0.3)
    bm = jax.random.normal(ks[3], (g, s, n)) * 0.3
    cm = jax.random.normal(ks[4], (g, s, n)) * 0.3
    got_y, got_f = ops.ssm_scan(x, dt, a, bm, cm, chunk=chunk)
    want_y, want_f = ref.ref_selective_scan(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want_f),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_state_carries_across_chunks():
    """Constant decay ~1 accumulates across the whole sequence; a chunking
    bug (state reset per chunk) would show up immediately."""
    g, s, p, n = 1, 256, 8, 4
    x = jnp.ones((g, s, p))
    dt = jnp.full((g, s), 0.001)      # tiny decay → near-pure accumulation
    a = jnp.full((g,), -0.01)
    bm = jnp.ones((g, s, n))
    cm = jnp.ones((g, s, n))
    y, _ = ops.ssm_scan(x, dt, a, bm, cm, chunk=64)
    # y grows ≈ linearly with t; the last value must be ≈ s · dt · n
    assert float(y[0, -1, 0]) > 0.9 * s * 0.001 * n


# ---------------------------------------------------------------------------
# ragged MoE GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,d,f,e,block_t", [
    (256, 64, 128, 4, 128),
    (512, 128, 64, 8, 128),
    (128, 32, 32, 3, 64),
])
def test_moe_gemm_matches_ref(t, d, f, e, block_t):
    rng = jax.random.PRNGKey(hash((t, d, f, e)) % 2**31)
    kx, kw, ko = jax.random.split(rng, 3)
    x = jax.random.normal(kx, (t, d))
    w = jax.random.normal(kw, (e, d, f)) / np.sqrt(d)
    # random ragged split of T rows over E experts (some may be empty)
    cuts = np.sort(np.asarray(
        jax.random.randint(ko, (e - 1,), 0, t + 1)))
    offsets = jnp.asarray(np.concatenate([[0], cuts, [t]]), jnp.int32)
    got = ops.moe_gemm(x, w, offsets, block_t=block_t)
    want = ref.ref_moe_gemm(x, w, offsets)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_moe_gemm_empty_experts():
    t, d, f, e = 128, 32, 32, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (e, d, f))
    offsets = jnp.array([0, 0, t, t, t], jnp.int32)   # only expert 1 active
    got = ops.moe_gemm(x, w, offsets, block_t=64)
    want = x @ w[1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# model-layer integration: chunked SSD algebra vs sequential oracle
# ---------------------------------------------------------------------------

def test_ssd_chunked_module_matches_sequential_scan():
    """models.ssm.ssd_chunked (matmul form) ≡ sequential recurrence."""
    from repro.configs.base import ArchConfig
    from repro.models import ssm as SSM

    cfg = ArchConfig(name="t", family="hybrid", n_layers=1, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
                     ssm_state=16, ssm_head_dim=32, dtype="float32",
                     param_dtype="float32")
    rng = jax.random.PRNGKey(0)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pin = 2 * di + 2 * n + h
    p = {"w_in": jax.random.normal(rng, (d, pin)) * 0.05,
         "dt_bias": jnp.zeros((h,)),
         "a_log": jnp.zeros((h,)),
         "d_skip": jnp.ones((h,)),
         "w_out": jax.random.normal(jax.random.PRNGKey(1), (di, d)) * 0.05}
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 256, d)) * 0.3

    y_chunked, fin = SSM.ssd_chunked(p, cfg, x)

    # sequential: run the same recurrence one token at a time
    state = jnp.zeros((2, h, cfg.ssm_head_dim, n))
    ys = []
    for t in range(x.shape[1]):
        yt, state = SSM.ssd_decode_step(p, cfg, x[:, t:t + 1], state)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_seq),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(state),
                               rtol=5e-4, atol=5e-4)


def test_mlstm_parallel_matches_decode_steps():
    from repro.configs.base import ArchConfig
    from repro.models import xlstm as XL

    cfg = ArchConfig(name="t", family="ssm", n_layers=1, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=0, vocab=128,
                     dtype="float32", param_dtype="float32")
    rng = jax.random.PRNGKey(0)
    d, di = cfg.d_model, cfg.d_inner
    ks = jax.random.split(rng, 5)
    p = {"wq": jax.random.normal(ks[0], (d, di)) * 0.05,
         "wk": jax.random.normal(ks[1], (d, di)) * 0.05,
         "wv": jax.random.normal(ks[2], (d, di)) * 0.05,
         "w_gate": jax.random.normal(ks[3], (d, 2 * cfg.n_heads)) * 0.05,
         "w_out": jax.random.normal(ks[4], (di, d)) * 0.05}
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 256, d)) * 0.3

    y_par, (cf, nf) = XL.mlstm_parallel(p, cfg, x)

    h, pd = cfg.n_heads, di // cfg.n_heads
    state = (jnp.zeros((2, h, pd, pd)), jnp.zeros((2, h, pd)))
    ys = []
    for t in range(x.shape[1]):
        yt, state = XL.mlstm_decode_step(p, cfg, x[:, t:t + 1], state)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cf), np.asarray(state[0]),
                               rtol=1e-3, atol=1e-3)


def test_model_pallas_attention_path_matches_ref():
    """cfg.attn_impl='pallas' must reproduce the jnp model end to end
    (forward + prefill + decode) in interpret mode."""
    import dataclasses
    from repro.configs.base import reduced
    from repro.configs.registry import ARCHS
    from repro.models.model import Model

    base_cfg = reduced(ARCHS["granite-3-2b"])
    cfg_p = dataclasses.replace(base_cfg, attn_impl="pallas",
                                sliding_window=0, long_context_window=0)
    cfg_r = dataclasses.replace(base_cfg, sliding_window=0,
                                long_context_window=0)
    m_r, m_p = Model(cfg_r), Model(cfg_p)
    rng = jax.random.PRNGKey(0)
    params = m_r.init(rng)
    tokens = jax.random.randint(rng, (2, 32), 0, cfg_r.vocab)
    f_r, _ = m_r.forward(params, {"tokens": tokens})
    f_p, _ = m_p.forward(params, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(f_r), np.asarray(f_p),
                               rtol=1e-4, atol=1e-4)

    _, cache = m_p.prefill(params, {"tokens": tokens[:, :28]}, 40)
    l_r, _ = m_r.decode_step(params, cache, tokens[:, 28:29],
                             jnp.asarray(28))
    l_p, _ = m_p.decode_step(params, cache, tokens[:, 28:29],
                             jnp.asarray(28))
    np.testing.assert_allclose(np.asarray(l_r), np.asarray(l_p),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused RMSNorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 128, 256), (4, 96, 512), (1, 1, 64),
                                   (300, 128)])
def test_rmsnorm_matches_ref(shape, dtype):
    rng = jax.random.PRNGKey(hash(shape) % 2**31)
    x = jax.random.normal(rng, shape, dtype)
    scale = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], dtype) + 1.0
    got = ops.rmsnorm(x, scale, block_r=64)
    want = ref.ref_rmsnorm(x, scale)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rms_norm
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 128))
    scale = jnp.ones((128,)) * 1.5
    got = ops.rmsnorm(x, scale)
    want = rms_norm(x, scale, eps=1e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# masked segmented argmin/argmax scoring (scheduler selection kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("is_max", [True, False])
@pytest.mark.parametrize("b,n", [(1, 32), (8, 64), (5, 200), (16, 128)])
def test_sched_argext_kernel_matches_ref(b, n, is_max):
    """Pallas kernel (interpret mode) ≡ jnp oracle over random masks."""
    from repro.kernels import sched_ops

    rng = np.random.default_rng(hash((b, n, is_max)) % 2**31)
    scores = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
    mask = jnp.asarray(rng.random((b, n)) < 0.4)
    got_i, got_v = sched_ops.masked_argext(scores, mask, is_max=is_max,
                                           interpret=True)
    want_i, want_v = ref.ref_masked_argext(scores, mask, is_max=is_max)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


def test_sched_argext_property_random_masks():
    """Hypothesis sweep: any (shape, scores, mask) agrees with the oracle,
    including all-False and all-True mask rows and tied scores."""
    try:
        import hypothesis as hyp
        from hypothesis import strategies as st
    except ImportError:  # container without the [test] extra: shim
        import _minihyp as hyp
        from _minihyp import strategies as st
    from repro.kernels import sched_ops

    @hyp.settings(max_examples=40, deadline=None)
    @hyp.given(b=st.integers(1, 6), n=st.integers(1, 70),
               seed=st.integers(0, 2**31 - 1), is_max=st.booleans(),
               p=st.sampled_from([0.0, 0.15, 0.6, 1.0]),
               quantize=st.booleans())
    def run(b, n, seed, is_max, p, quantize):
        rng = np.random.default_rng(seed)
        scores = rng.normal(size=(b, n)).astype(np.float32)
        if quantize:                      # force ties
            scores = np.round(scores)
        mask = rng.random((b, n)) < p
        got_i, got_v = sched_ops.masked_argext(
            jnp.asarray(scores), jnp.asarray(mask), is_max=is_max,
            interpret=True)
        want_i, want_v = ref.ref_masked_argext(
            jnp.asarray(scores), jnp.asarray(mask), is_max=is_max)
        np.testing.assert_array_equal(np.asarray(got_i),
                                      np.asarray(want_i))
        np.testing.assert_array_equal(np.asarray(got_v),
                                      np.asarray(want_v))

    run()


def test_sched_argext_all_masked_rows_return_minus_one():
    from repro.kernels import sched_ops

    scores = jnp.arange(24, dtype=jnp.float32).reshape(2, 12)
    mask = jnp.zeros((2, 12), bool).at[1, 3].set(True)
    idx, val = sched_ops.masked_argext(scores, mask, is_max=True,
                                       interpret=True)
    assert idx.tolist() == [-1, 3]
    assert float(val[1]) == 15.0


def test_sched_argext_ties_break_to_first_index():
    from repro.kernels import sched_ops

    scores = jnp.asarray([[2.0, 5.0, 5.0, 1.0, 5.0]])
    mask = jnp.ones((1, 5), bool)
    for interpret in (True, None):   # kernel body and the CPU jnp path
        idx, _ = sched_ops.masked_argmax(scores, mask, interpret=interpret)
        assert int(idx[0]) == 1
        idx, _ = sched_ops.masked_argmin(
            jnp.asarray([[3.0, 1.0, 4.0, 1.0, 9.0]]), mask,
            interpret=interpret)
        assert int(idx[0]) == 1


def _fleet_hot_path_cases(rng):
    """Score/mask tensors shaped and distributed like the fleet tick's
    three selection call sites (see repro.sim.fleet_jax):

    * ``steal_select``  — (Qc=64,) per edge: rank scores from a small tied
      set, steal-only candidates offset by +1e12;
    * ``export_select`` — (Q=32,) per edge: slack scores, empty slots at
      +POS, sparse candidate masks;
    * ``peer_offload``  — (E,) across the fleet: queue loads with invalid
      edges parked at +POS, down to the 2-edge minimum.
    """
    from repro.kernels.sched_ops import POS

    ranks = np.asarray([0.57, 0.43, 0.35, -0.012])   # Table-1 steal ranks
    for e in (1, 4, 8):
        score = ranks[rng.integers(0, 4, (e, 64))] \
            + np.where(rng.random((e, 64)) < 0.3, 1e12, 0.0)
        yield True, score.astype(np.float32), rng.random((e, 64)) < 0.5
        slack = rng.normal(0, 400.0, (e, 32))
        slack[rng.random((e, 32)) < 0.4] = POS       # empty queue slots
        yield False, slack.astype(np.float32), rng.random((e, 32)) < 0.3
    for e in (2, 3, 8):
        load = np.abs(rng.normal(500.0, 300.0, (1, e)))
        load[rng.random((1, e)) < 0.2] = POS         # padded edges
        yield False, load.astype(np.float32), np.ones((1, e), bool)


def test_sched_argext_interpret_parity_on_fleet_hot_path_shapes():
    """ROADMAP close-out: the Pallas kernel body (interpret mode, i.e.
    the exact Mosaic lowering input) agrees with the jnp reference the
    CPU hot path traces, over the fleet's *actual* call shapes and score
    distributions — sentinel offsets, ±POS fills, tied ranks, all-masked
    rows included."""
    from repro.kernels import sched_ops

    rng = np.random.default_rng(0xf1ee7)
    n_cases = 0
    for is_max, scores, mask in _fleet_hot_path_cases(rng):
        if n_cases == 0:
            mask = np.zeros_like(mask)               # all-ineligible row
        got_i, got_v = sched_ops.masked_argext(
            jnp.asarray(scores), jnp.asarray(mask), is_max=is_max,
            interpret=True)
        want_i, want_v = ref.ref_masked_argext(
            jnp.asarray(scores), jnp.asarray(mask), is_max=is_max)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i),
                                      err_msg=f"case {n_cases}")
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v),
                                      err_msg=f"case {n_cases}")
        n_cases += 1
    assert n_cases == 9


def test_sched_argext_nd_batch_shapes():
    from repro.kernels import sched_ops

    scores = jnp.asarray(np.random.default_rng(0).normal(
        size=(3, 4, 40)).astype(np.float32))
    mask = jnp.asarray(np.random.default_rng(1).random((3, 4, 40)) < 0.5)
    got_i, got_v = sched_ops.masked_argmin(scores, mask, interpret=True)
    want_i, want_v = ref.ref_masked_argext(scores, mask, is_max=False)
    assert got_i.shape == (3, 4)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_moe_gemm_bf16(dtype):
    t, d, f, e = 256, 64, 64, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (e, d, f)) /
         np.sqrt(d)).astype(dtype)
    offsets = jnp.array([0, 64, 128, 192, 256], jnp.int32)
    got = ops.moe_gemm(x, w, offsets, block_t=64)
    want = ref.ref_moe_gemm(x.astype(jnp.float32), w.astype(jnp.float32),
                            offsets)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)
