"""Shared fixtures: the retrace guard for the policy-generic tick program."""
import pytest


@pytest.fixture
def compile_guard():
    """Fail if the fleet tick program retraces after the guard is armed.

    The tick program is policy-generic: every policy is runtime
    ``PolicyParams`` data, so once a program has traced for a given
    input shape, running *other policies* through the same shapes must
    not trace again — a second trace means some runtime input (usually
    a policy field) leaked into the static/trace-level signature.

    Usage: run one policy to pay the legitimate shape-driven trace,
    ``compile_guard.arm()``, then run the other policies; teardown
    asserts the jit trace count across all cached tick programs never
    grew past the armed baseline.
    """
    from repro.obs.prof import fleet_compile_stats

    class Guard:
        baseline = None

        def arm(self) -> None:
            self.baseline = fleet_compile_stats().traces

    g = Guard()
    yield g
    if g.baseline is not None:
        stats = fleet_compile_stats()
        assert stats.traces == g.baseline, (
            f"fleet tick program retraced after the guard was armed: "
            f"{stats.traces - g.baseline} new jit trace(s) across "
            f"{stats.programs} cached programs — PolicyParams leaked "
            f"into a static argument")
