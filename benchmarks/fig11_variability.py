"""Paper Fig. 11/12 + App. C (Fig. 21): DEMS-A under network variability.

Latency shaping: the §8.5 trapezium waveform (0→400 ms).  Bandwidth
shaping: synthetic cellular traces (Fig. 2c analogue).  Expectation:
DEMS-A ≥ DEMS on QoS utility with similar on-time tasks (paper: +16–27 %).

``main_fleet`` repeats the latency-shaped comparison on the JAX fleet
simulator and adds the congestion regimes (``cloud-crunch``: a saturated
finite FaaS pool; ``bw-fade``: a cellular deep fade): the seed sweep for
each policy runs as one compiled program (`run_fleet_batch`), checking
that the vmapped DEMS-A adaptation shows the same qualitative gain as
the event-driven oracle now that the fleet cloud is contended and
bandwidth-shaped rather than elastic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import QOS, Rows, timed
from repro.core.schedulers import make_policy
from repro.sim.engine import run_policy
from repro.sim.network import (CloudLatencyModel, cellular_bandwidth_trace,
                               trapezium)
from repro.sim.workloads import standard


def main(quick: bool = False, rows: Rows | None = None) -> dict:
    rows = rows or Rows()
    workloads = ("4D-P",) if quick else ("4D-P", "3D-P")
    seeds = (7,) if quick else (7, 17, 27)
    duration = 300_000.0
    out = {}
    for wl in workloads:
        arrivals = standard(wl, duration_ms=duration, seed=1)
        for variability in ("latency", "bandwidth"):
            if variability == "latency":
                cm = CloudLatencyModel(latency_at=trapezium())
            else:
                cm = CloudLatencyModel(
                    bandwidth_at=cellular_bandwidth_trace(seed=3))
            gains, comps = [], []
            for seed in seeds:
                kw = dict(QOS, cloud_model=cm)
                base, _ = timed(lambda: run_policy(
                    make_policy("DEMS"), arrivals, duration, seed=seed,
                    **kw))
                adpt, us = timed(lambda: run_policy(
                    make_policy("DEMS-A"), arrivals, duration, seed=seed,
                    **kw))
                gains.append(100 * (adpt.qos_utility / base.qos_utility - 1))
                comps.append(adpt.completed / max(base.completed, 1))
                out[(wl, variability, seed)] = (base, adpt)
            rows.add(f"fig11/{wl}/{variability}", us,
                     f"DEMS-A qos {np.median(gains):+.1f}% "
                     f"(all {[f'{g:+.0f}' for g in gains]}), tasks "
                     f"x{np.median(comps):.2f} (paper: +15..27% qos)")
    return out


def main_fleet(quick: bool = False, rows: Rows | None = None) -> dict:
    """Fleet-side Fig. 11: DEMS-A vs DEMS under the §8.5 trapezium *and*
    under the congestion scenarios (finite cloud pool, bandwidth fade),
    every per-policy seed sweep batched into a single jit."""
    from repro.scenarios import (ScenarioSpec, ThetaTrapezium,
                                 fleet_summary_batch, get,
                                 run_scenario_fleet_batch)

    rows = rows or Rows()
    spec = ScenarioSpec(name="fig11-fleet", theta=ThetaTrapezium(),
                        duration_ms=120_000.0 if quick else 300_000.0)
    if quick:   # compress the 300 s trapezium into the shorter mission
        spec = dataclasses.replace(spec, theta=ThetaTrapezium(
            ramp_up=(24_000.0, 36_000.0), ramp_down=(84_000.0, 96_000.0)))
    seeds = (7,) if quick else (7, 17, 27)
    duration = 60_000.0 if quick else 300_000.0
    out = {}
    runs = [("latency", spec),
            ("cloud-crunch", get("cloud-crunch", duration_ms=duration)),
            ("bw-fade", get("bw-fade", duration_ms=duration))]
    for label, sc in runs:
        base, _ = timed(lambda: fleet_summary_batch(
            run_scenario_fleet_batch(sc, "DEMS", seeds)))
        adpt, us = timed(lambda: fleet_summary_batch(
            run_scenario_fleet_batch(sc, "DEMS-A", seeds)))
        gains = [100 * (a["qos_utility"] / b["qos_utility"] - 1)
                 for a, b in zip(adpt, base)]
        out[label] = (base, adpt)
        rows.add(f"fig11/fleet/{label}", us,
                 f"DEMS-A qos {np.median(gains):+.1f}% over {len(seeds)} "
                 f"seeds (one-jit batch; paper oracle: +15..27%)")

    # Fig. 12: adaptation dynamics — the per-tick t̂ trace carried out of
    # the scan (FleetResult.t_hat) shows the estimator inflating with the
    # trapezium and cooling back down once θ drops
    out["trace"], us = timed(
        lambda: adaptation_trace(spec, "DEMS-A", seeds[0]))
    rows.add("fig12/fleet/t_hat", us,
             f"t̂ inflation: peak +{out['trace']['peak_ms']:.0f} ms, "
             f"{100 * out['trace']['inflated_frac']:.0f}% of mission "
             f"above static (per-tick trace)")
    return out


def adaptation_trace(spec, policy: str, seed: int = 7) -> dict:
    """Fig. 12-style adaptation dynamics from the fleet t̂ telemetry.

    Runs one scenario with ``trace=TraceSpec(t_hat=True)`` (the
    flight recorder) and reduces the per-tick
    ``t_hat`` trace ``[T, E, M]`` (DEMS-A's adapted cloud-latency
    estimate) to inflation statistics against the static Table-1 t̂.
    """
    import dataclasses as dc

    from repro.obs import TraceSpec
    from repro.scenarios import run_scenario_fleet

    res = run_scenario_fleet(dc.replace(spec, seed=seed), policy,
                             trace=TraceSpec(t_hat=True))
    t_hat = np.asarray(res.t_hat)                      # [T, E, M]
    static = np.asarray([m.t_cloud for m in spec.models])
    excess = t_hat - static[None, None, :]
    return dict(peak_ms=float(excess.max()),
                inflated_frac=float((excess.max(axis=(1, 2)) > 1.0).mean()),
                t_hat=t_hat)


if __name__ == "__main__":
    rows = Rows()
    main(rows=rows)
    main_fleet(rows=rows)
    rows.emit()
