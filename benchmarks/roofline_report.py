"""Aggregate dry-run JSONs into the §Dry-run / §Roofline tables
(markdown printed to stdout; also summarized as CSV rows)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Rows

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_all(path: str = DRYRUN_DIR) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def markdown_table(results: list[dict]) -> str:
    lines = [
        "| arch | shape | compile(s) | mem GB/dev | coll GB/dev | "
        "compute ms | memory ms | collective ms | bottleneck | "
        "MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — |"
                         f" — | — | SKIP: {r['skipped'][:40]}… | — |")
            continue
        ms = r.get("mesh_single", {})
        rf = r.get("roofline", {})
        if not ms.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | |"
                         f" {ms.get('error', '?')[:40]} | |")
            continue
        mem = (ms["memory"]["argument_bytes"]
               + ms["memory"]["temp_bytes"]) / 1e9
        coll = ms["collective_bytes"]["total"] / 1e9
        if "compute_s" in rf:
            ratio = rf.get("model_vs_hlo_flops")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {ms['compile_s']} | "
                f"{mem:.1f} | {coll:.2f} | {rf['compute_s'] * 1e3:.1f} | "
                f"{rf['memory_s'] * 1e3:.1f} | "
                f"{rf['collective_s'] * 1e3:.1f} | {rf['bottleneck']} | "
                f"{ratio:.2f} |" if ratio else
                f"| {r['arch']} | {r['shape']} | {ms['compile_s']} | "
                f"{mem:.1f} | {coll:.2f} | | | | | |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | "
                         f"{ms['compile_s']} | {mem:.1f} | {coll:.2f} | "
                         f"| | | (no roofline) | |")
    return "\n".join(lines)


def main(quick: bool = False, rows: Rows | None = None) -> list[dict]:
    rows = rows or Rows()
    results = load_all()
    ok = sum(1 for r in results if r.get("mesh_single", {}).get("ok")
             or "skipped" in r)
    multi_ok = sum(1 for r in results if r.get("mesh_multi", {}).get("ok")
                   or "skipped" in r)
    skipped = sum(1 for r in results if "skipped" in r)
    rows.add("roofline/combos_single_ok", 0.0,
             f"{ok}/{len(results)} (skips: {skipped})")
    rows.add("roofline/combos_multi_ok", 0.0, f"{multi_ok}/{len(results)}")
    for r in results:
        rf = r.get("roofline", {})
        if "bottleneck" in rf:
            rows.add(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                     f"{rf['bottleneck']}-bound "
                     f"c={rf['compute_s'] * 1e3:.1f}ms "
                     f"m={rf['memory_s'] * 1e3:.1f}ms "
                     f"l={rf['collective_s'] * 1e3:.1f}ms")
    return results


if __name__ == "__main__":
    print(markdown_table(load_all()))
