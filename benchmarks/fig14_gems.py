"""Paper Fig. 14/15: GEMS vs DEMS on the QoE workloads WL1/WL2.

Two regimes (see benchmarks/common.py): the faithful §8.7 sleep-semantics
elastic-cloud setup, and a constrained-cloud/bursty-edge stress regime
where queue-wait failures dominate and GEMS's preemptive rescheduling has
the most headroom.  Medians over 5 seeds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import GEMS_SLEEP, GEMS_STRESS, Rows, timed
from repro.core.schedulers import make_policy
from repro.sim.engine import run_policy
from repro.sim.workloads import gems_workload


def main(quick: bool = False, rows: Rows | None = None) -> dict:
    rows = rows or Rows()
    seeds = (101,) if quick else (101, 102, 103, 104, 105)
    duration = 300_000.0
    out = {}
    regimes = {"sleep": (GEMS_SLEEP, 5), "stress": (GEMS_STRESS, 3)}
    for regime, (kw, drones) in regimes.items():
        for wl in ("WL1", "WL2"):
            for alpha in (0.9, 1.0):
                arrivals = gems_workload(wl, alpha, n_drones=drones, seed=2)
                dq, dt, rs, qoe_abs, qoe_b = [], [], [], [], []
                for seed in seeds:
                    d, _ = timed(lambda: run_policy(
                        make_policy("DEMS"), arrivals, duration, seed=seed,
                        **kw))
                    g, us = timed(lambda: run_policy(
                        make_policy("GEMS"), arrivals, duration, seed=seed,
                        **kw))
                    gb, _ = timed(lambda: run_policy(
                        make_policy("GEMS-B"), arrivals, duration,
                        seed=seed, **kw))
                    dq.append(100 * (g.qoe_utility /
                                     max(d.qoe_utility, 1) - 1))
                    dt.append(100 * (g.total_utility / d.total_utility - 1))
                    rs.append(g.gems_rescheduled)
                    qoe_abs.append((d.qoe_utility, g.qoe_utility))
                    qoe_b.append(gb.qoe_utility)
                    out[(regime, wl, alpha, seed)] = (d, g, gb)
                rows.add(f"fig14/{regime}/{wl}/a{alpha}", us,
                         f"dQoE med {np.median(dq):+.0f}% "
                         f"dTotal {np.median(dt):+.1f}% "
                         f"resched~{int(np.median(rs))} "
                         f"QoE {np.median([a for a, _ in qoe_abs]):.0f}"
                         f"->{np.median([b for _, b in qoe_abs]):.0f} "
                         f"(GEMS-B {np.median(qoe_b):.0f})")
    return out


if __name__ == "__main__":
    rows = Rows()
    main(rows=rows)
    rows.emit()
