"""Paper Fig. 10: incremental benefit of migration (DEM) and stealing
(DEMS) over the E+C baseline."""
from __future__ import annotations

from benchmarks.common import QOS, Rows, timed
from repro.core.schedulers import make_policy
from repro.sim.engine import run_policy
from repro.sim.workloads import STANDARD_WORKLOADS, standard


def main(quick: bool = False, rows: Rows | None = None) -> dict:
    rows = rows or Rows()
    workloads = ("4D-P", "4D-A") if quick else STANDARD_WORKLOADS
    duration = 120_000.0 if quick else 300_000.0
    out = {}
    for wl in workloads:
        arrivals = standard(wl, duration_ms=duration, seed=1)
        for pol in ("EDF-E+C", "DEM", "DEMS"):
            r, us = timed(lambda: run_policy(
                make_policy(pol), arrivals, duration, seed=7, **QOS))
            out[(wl, pol)] = r
            rows.add(f"fig10/{wl}/{pol}", us,
                     f"tasks={r.completed} qos={r.qos_utility:.0f} "
                     f"migrated={r.migrated} stolen={r.stolen} "
                     f"edge_util={100 * r.edge_utilization:.0f}%")
        e, d, s = (out[(wl, p)] for p in ("EDF-E+C", "DEM", "DEMS"))
        rows.add(f"fig10/{wl}/delta", 0.0,
                 f"DEM qos {100 * (d.qos_utility / e.qos_utility - 1):+.1f}% "
                 f"DEMS tasks {100 * (s.completed / e.completed - 1):+.1f}% "
                 f"qos {100 * (s.qos_utility / e.qos_utility - 1):+.1f}% "
                 f"(paper 4D-A: +10% tasks, +5% qos)")
    return out


if __name__ == "__main__":
    rows = Rows()
    main(rows=rows)
    rows.emit()
