"""Flight-recorder CLI: decision timeline + tail table for any run.

Runs one registry scenario under one fleet policy with the full
:class:`~repro.obs.trace.TraceSpec` and renders what the compiled tick
program decided, tick by tick — admissions, dispatches, drops by cause,
steals/migrations/peer offloads, queue depths — plus the paper's tail
scoreboard (p50/p95/p99 deadline slack and completion latency, windowed
p95/p99 deadline-hit rates, per-task-type QoE success frequencies).

    PYTHONPATH=src python benchmarks/fleet_trace.py \\
        --scenario cloud-crunch --policy DEMS-A --duration-ms 20000
    PYTHONPATH=src python benchmarks/fleet_trace.py --scenario rush-hour \\
        --policy GEMS-COOP --json trace.json --perfetto trace.pftrace.json

``--json``/``--csv`` dump the full per-tick series
(:func:`repro.obs.metrics.to_json` / ``to_csv``); ``--perfetto`` writes
a Chrome/Perfetto counter-track stream for ``ui.perfetto.dev``.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.obs import TraceSpec, metrics
from repro.scenarios import get, names, run_scenario_fleet


def timeline(ts: dict, dt: float, *, width: int = 12) -> str:
    """An aggregated per-window decision timeline (text)."""
    n = len(ts["arrivals"])
    win = max(1, n // width)
    cols = ("arrivals", "admit_edge", "admit_cloud", "edge_exec",
            "cloud_dispatch", "pool_blocked", "hit", "miss", "drop",
            "stolen", "migrated", "peer_out", "eq_depth", "cq_depth",
            "slots_busy")
    head = f"{'window':>14s} " + " ".join(f"{c[:9]:>9s}" for c in cols)
    lines = [head, "-" * len(head)]
    for w0 in range(0, n, win):
        w1 = min(w0 + win, n)
        t0, t1 = w0 * dt / 1e3, w1 * dt / 1e3
        row = [f"{t0:6.1f}-{t1:5.1f}s"]
        for c in cols:
            seg = ts[c][w0:w1]
            # gauges read better as window means, events as window sums
            v = seg.mean() if c in ("eq_depth", "cq_depth",
                                    "slots_busy") else seg.sum()
            row.append(f"{v:9.1f}" if isinstance(v, float) and c in (
                "eq_depth", "cq_depth", "slots_busy") else f"{int(v):9d}")
        lines.append(" ".join(row))
    return "\n".join(lines)


def tail_table(tm: dict) -> str:
    lines = [
        f"settled: {tm['hit']} hit / {tm['miss']} miss / "
        f"{tm['drop']} drop   hit-rate {100 * tm['hit_rate']:.1f}%",
        f"drops by cause: infeasible={tm['drops_by_cause']['infeasible']} "
        f"unstolen={tm['drops_by_cause']['unstolen']} "
        f"queue_full={tm['drops_by_cause']['queue_full']}",
        f"QoS utility {tm['qos_utility']:.0f}   "
        f"QoE utility {tm['qoe_utility']:.0f}",
        f"{'':16s} {'p50':>8s} {'p95':>8s} {'p99':>8s}   (ms)",
        "deadline slack  " + " ".join(
            f"{tm['slack_ms'][p]:8.0f}" for p in ("p50", "p95", "p99")),
        "completion lat  " + " ".join(
            f"{tm['latency_ms'][p]:8.0f}" for p in ("p50", "p95", "p99")),
        f"deadline-hit tail (per ~1s window): "
        f"mean {100 * tm['deadline_hit']['mean']:.1f}%  "
        f"p95 {100 * tm['deadline_hit']['p95']:.1f}%  "
        f"p99 {100 * tm['deadline_hit']['p99']:.1f}%",
        "QoE frequency (per task type): " + "  ".join(
            f"{k}={100 * v:.1f}%" for k, v in tm["qoe_frequency"].items()),
    ]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="rush-hour", choices=names())
    ap.add_argument("--policy", default="DEMS-A")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--duration-ms", type=float, default=None)
    ap.add_argument("--dt", type=float, default=25.0)
    ap.add_argument("--hist-bins", type=int, default=64)
    ap.add_argument("--hist-max-ms", type=float, default=4000.0)
    ap.add_argument("--windows", type=int, default=12,
                    help="timeline rows (ticks aggregate into windows)")
    ap.add_argument("--json", help="write full metrics document here")
    ap.add_argument("--csv", help="write per-tick series CSV here")
    ap.add_argument("--perfetto", help="write Chrome/Perfetto trace here")
    args = ap.parse_args()

    overrides = dict(seed=args.seed)
    if args.duration_ms is not None:
        overrides["duration_ms"] = args.duration_ms
    spec = get(args.scenario, **overrides)
    tspec = TraceSpec.full(hist_bins=args.hist_bins,
                           hist_max_ms=args.hist_max_ms)
    res = run_scenario_fleet(spec, args.policy, dt=args.dt, trace=tspec)
    metrics.check_conservation(res.counters)

    ts = metrics.time_series(res.counters)
    tm = metrics.tail_metrics(res.counters, tspec, list(spec.model_names))
    n_edges = np.asarray(res.counters.valid).shape[1]
    print(f"{spec.name} × {args.policy} seed={args.seed} "
          f"({spec.duration_ms / 1e3:.0f}s, dt={args.dt:.0f}ms, "
          f"{len(ts['arrivals'])} ticks, {n_edges} edges)\n")
    print(timeline(ts, args.dt, width=args.windows))
    print()
    print(tail_table(tm))
    print("\ntask conservation: arrived = settled + in-flight "
          "(residual 0 on every tick) ✓")

    if args.json:
        with open(args.json, "w") as f:
            f.write(metrics.to_json(res.counters, tspec,
                                    list(spec.model_names), indent=2))
        print(f"wrote {args.json}")
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(metrics.to_csv(res.counters))
        print(f"wrote {args.csv}")
    if args.perfetto:
        with open(args.perfetto, "w") as f:
            f.write(metrics.to_perfetto(res.counters, dt_ms=args.dt))
        print(f"wrote {args.perfetto}")


if __name__ == "__main__":
    main()
