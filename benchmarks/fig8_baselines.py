"""Paper Fig. 8/9: DEMS vs the seven baselines across the six workloads.

Reports per (policy × workload): tasks completed %, QoS utility, and the
paper's headline ratios (DEMS completion range, utility multiple vs the
weakest baseline).
"""
from __future__ import annotations

from benchmarks.common import QOS, Rows, timed
from repro.core.schedulers import BASELINES, make_policy
from repro.sim.engine import run_policy
from repro.sim.workloads import STANDARD_WORKLOADS, standard

POLICIES = BASELINES + ("DEMS",)


def main(quick: bool = False, rows: Rows | None = None) -> dict:
    rows = rows or Rows()
    workloads = ("2D-P", "3D-A") if quick else STANDARD_WORKLOADS
    duration = 120_000.0 if quick else 300_000.0
    out: dict[tuple[str, str], object] = {}
    for wl in workloads:
        arrivals = standard(wl, duration_ms=duration, seed=1)
        for pol in POLICIES:
            r, us = timed(lambda: run_policy(
                make_policy(pol), arrivals, duration, seed=7, **QOS))
            out[(wl, pol)] = r
            rows.add(f"fig8/{wl}/{pol}", us,
                     f"completed={100 * r.completion_rate:.1f}% "
                     f"qos={r.qos_utility:.0f}")
    # headline claims
    dems = [out[(wl, "DEMS")] for wl in workloads]
    comp = [r.completion_rate for r in dems]
    ratios = []
    for wl in workloads:
        base_best = max(out[(wl, p)].qos_utility for p in BASELINES)
        base_worst = min(out[(wl, p)].qos_utility for p in BASELINES)
        ratios.append(out[(wl, "DEMS")].qos_utility / max(base_worst, 1))
        rows.add(f"fig8/{wl}/DEMS_vs_best_baseline", 0.0,
                 f"x{out[(wl, 'DEMS')].qos_utility / max(base_best, 1):.2f}")
    rows.add("fig8/DEMS_completion_range", 0.0,
             f"{100 * min(comp):.0f}%..{100 * max(comp):.0f}% "
             f"(paper: 77..88%)")
    rows.add("fig8/DEMS_utility_vs_worst_baseline", 0.0,
             f"up to x{max(ratios):.1f} (paper: up to x2.7)")
    return out


if __name__ == "__main__":
    rows = Rows()
    main(rows=rows)
    rows.emit()
