"""Paper Fig. 8/9: DEMS vs the seven baselines across the six workloads.

Reports per (policy × workload): tasks completed %, QoS utility, and the
paper's headline ratios (DEMS completion range, utility multiple vs the
weakest baseline).

``--backend fleet`` repeats the whole comparison on the JAX fleet
simulator: every baseline is a runtime ``PolicyParams`` branch of the
same compiled tick program, so the full workload × policy grid runs as
**one** ``run_batch`` program instead of one event-driven simulation per
cell — the coverage-matrix close-out that lets fleet-scale sweeps
reproduce the paper's baseline claims without the oracle.
"""
from __future__ import annotations

import argparse

from benchmarks.common import QOS, Rows, timed
from repro.core.schedulers import BASELINES, make_policy
from repro.sim.engine import run_policy
from repro.sim.workloads import STANDARD_WORKLOADS, standard

POLICIES = BASELINES + ("DEMS",)


def main(quick: bool = False, rows: Rows | None = None) -> dict:
    rows = rows or Rows()
    workloads = ("2D-P", "3D-A") if quick else STANDARD_WORKLOADS
    duration = 120_000.0 if quick else 300_000.0
    out: dict[tuple[str, str], object] = {}
    for wl in workloads:
        arrivals = standard(wl, duration_ms=duration, seed=1)
        for pol in POLICIES:
            r, us = timed(lambda: run_policy(
                make_policy(pol), arrivals, duration, seed=7, **QOS))
            out[(wl, pol)] = r
            rows.add(f"fig8/{wl}/{pol}", us,
                     f"completed={100 * r.completion_rate:.1f}% "
                     f"qos={r.qos_utility:.0f}")
    # headline claims
    dems = [out[(wl, "DEMS")] for wl in workloads]
    comp = [r.completion_rate for r in dems]
    ratios = []
    for wl in workloads:
        base_best = max(out[(wl, p)].qos_utility for p in BASELINES)
        base_worst = min(out[(wl, p)].qos_utility for p in BASELINES)
        ratios.append(out[(wl, "DEMS")].qos_utility / max(base_worst, 1))
        rows.add(f"fig8/{wl}/DEMS_vs_best_baseline", 0.0,
                 f"x{out[(wl, 'DEMS')].qos_utility / max(base_best, 1):.2f}")
    rows.add("fig8/DEMS_completion_range", 0.0,
             f"{100 * min(comp):.0f}%..{100 * max(comp):.0f}% "
             f"(paper: 77..88%)")
    rows.add("fig8/DEMS_utility_vs_worst_baseline", 0.0,
             f"up to x{max(ratios):.1f} (paper: up to x2.7)")
    return out


def main_fleet(quick: bool = False, rows: Rows | None = None) -> dict:
    """Fig. 8 on the fleet backend: workloads × (baselines + DEMS) as one
    compiled program (policy flags are runtime, shapes padded per
    workload by ``build_fleet_batch``)."""
    import jax

    from repro.core.task import ACTIVE, PASSIVE
    from repro.scenarios import (DroneSpec, ScenarioSpec, compile_fleet,
                                 fleet_summary)
    from repro.sim.fleet_jax import build_fleet_batch, run_batch

    rows = rows or Rows()
    workloads = ("2D-P", "3D-A") if quick else STANDARD_WORKLOADS
    duration = 120_000.0 if quick else 300_000.0
    runs, tags = [], []
    for wl in workloads:
        names = PASSIVE if wl.endswith("P") else ACTIVE
        spec = ScenarioSpec(
            name=wl, model_names=names, duration_ms=duration, seed=1,
            drones=tuple(DroneSpec() for _ in range(int(wl[0]))),
            cloud_concurrency=QOS["cloud_concurrency"])
        sig = compile_fleet(spec)
        for pol in POLICIES:
            runs.append((spec.models, pol, sig, spec.cloud_concurrency))
            tags.append((wl, pol))
    batch = build_fleet_batch(runs)
    final, us = timed(lambda: jax.device_get(run_batch(batch)))
    out: dict[tuple[str, str], dict] = {}
    for i, (wl, pol) in enumerate(tags):
        s = fleet_summary(jax.tree.map(lambda a, i=i: a[i], final))
        out[(wl, pol)] = s
        rows.add(f"fig8/fleet/{wl}/{pol}", us / len(tags),
                 f"completed={100 * s['completion_rate']:.1f}% "
                 f"qos={s['qos_utility']:.0f}")
    comp, ratios = [], []
    for wl in workloads:
        dems = out[(wl, "DEMS")]
        comp.append(dems["completion_rate"])
        base_best = max(out[(wl, p)]["qos_utility"] for p in BASELINES)
        base_worst = min(out[(wl, p)]["qos_utility"] for p in BASELINES)
        ratios.append(dems["qos_utility"] / max(base_worst, 1))
        rows.add(f"fig8/fleet/{wl}/DEMS_vs_best_baseline", 0.0,
                 f"x{dems['qos_utility'] / max(base_best, 1):.2f}")
    rows.add("fig8/fleet/DEMS_completion_range", 0.0,
             f"{100 * min(comp):.0f}%..{100 * max(comp):.0f}% "
             f"(one-program batch; paper oracle: 77..88%)")
    rows.add("fig8/fleet/DEMS_utility_vs_worst_baseline", 0.0,
             f"up to x{max(ratios):.1f} (paper: up to x2.7)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="oracle",
                    choices=("oracle", "fleet", "both"))
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    rows = Rows()
    if args.backend in ("oracle", "both"):
        main(quick=args.quick, rows=rows)
    if args.backend in ("fleet", "both"):
        main_fleet(quick=args.quick, rows=rows)
    rows.emit()
