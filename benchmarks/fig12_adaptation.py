"""Paper Fig. 12: adaptation dynamics — t̂ vs θ(t) overlay, per model.

Runs a θ-shaped mission under an adaptive policy with the flight
recorder (``trace=TraceSpec(t_hat=True)``)
and plots the scheduler's per-tick adapted cloud-latency estimate
t̂_m(t) (``FleetResult.t_hat``, carried out of the tick scan) against
the scenario's θ(t) waveform — one small-multiple panel per model, all
in milliseconds on one shared axis.  The estimator should inflate as the
trapezium rises (sliding-window average clears t̂+ε) and cool back to
the static Table-1 estimate once θ drops and the cooling period expires
(§5.4).

    PYTHONPATH=src python benchmarks/fig12_adaptation.py \
        --out benchmarks/figures/fig12_adaptation.png
    PYTHONPATH=src python benchmarks/fig12_adaptation.py --quick

Requires matplotlib (``pip install matplotlib``); everything else in the
benchmark suite stays matplotlib-free.
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib

import numpy as np

DEFAULT_OUT = pathlib.Path(__file__).parent / "figures" / \
    "fig12_adaptation.png"

# Validated categorical palette (fixed slot order — identity per model),
# plus ink/surface tokens; see docs/POLICIES.md for the policy being
# traced.  Text wears ink tokens, never the series color.
SERIES = ("#2a78d6", "#eb6834", "#1baf7a", "#eda100", "#e87ba4",
          "#008300", "#4a3aa7", "#e34948")
SURFACE, GRID = "#fcfcfb", "#e8e7e3"
INK, INK_2 = "#0b0b0b", "#52514e"
THETA_FILL, THETA_EDGE = "#dddcd7", "#b5b4ae"


def trace_spec(duration_ms: float):
    """The §8.5 trapezium mission used by the Fig. 11/12 fleet runs,
    ramps scaled into the requested horizon."""
    from repro.scenarios import ScenarioSpec, ThetaTrapezium

    d = duration_ms
    return ScenarioSpec(
        name="fig12-adaptation", duration_ms=d,
        theta=ThetaTrapezium(ramp_up=(0.2 * d, 0.3 * d),
                             ramp_down=(0.7 * d, 0.8 * d)))


def compute(spec, policy: str, seed: int, dt: float = 25.0) -> dict:
    """t̂ trace [T, M] (edge 0), θ trace [T], static t̂ and times [s]."""
    from repro.obs import TraceSpec
    from repro.scenarios import compile_fleet, run_scenario_fleet

    spec = dataclasses.replace(spec, seed=seed)
    res = run_scenario_fleet(spec, policy, dt=dt,
                             trace=TraceSpec(t_hat=True))
    sig = compile_fleet(spec, dt)
    return dict(
        times=np.asarray(sig.times) / 1e3,
        theta=np.asarray(sig.theta)[:, 0],
        t_hat=np.asarray(res.t_hat)[:, 0, :],
        static=np.asarray([m.t_cloud for m in spec.models]),
        names=list(spec.model_names))


def render(data: dict, policy: str, out: pathlib.Path) -> pathlib.Path:
    try:
        import matplotlib
    except ImportError as e:                          # pragma: no cover
        raise SystemExit(
            "fig12_adaptation needs matplotlib (pip install matplotlib); "
            "the rest of the benchmark suite runs without it") from e
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    names, times = data["names"], data["times"]
    n = len(names)
    ncols = 2 if n > 2 else n
    nrows = -(-n // ncols)
    fig, axes = plt.subplots(nrows, ncols, sharex=True, sharey=True,
                             figsize=(4.6 * ncols, 2.4 * nrows),
                             facecolor=SURFACE)
    axes = np.atleast_1d(axes).ravel()
    for ax in axes[n:]:
        ax.set_visible(False)
    for i, (name, ax) in enumerate(zip(names, axes)):
        ax.set_facecolor(SURFACE)
        ax.grid(True, color=GRID, linewidth=0.8)
        ax.set_axisbelow(True)
        for side in ("top", "right"):
            ax.spines[side].set_visible(False)
        for side in ("left", "bottom"):
            ax.spines[side].set_color(GRID)
        ax.tick_params(colors=INK_2, labelsize=8, length=0)
        # θ(t) context: same unit (ms of added WAN latency), neutral fill
        ax.fill_between(times, data["theta"], color=THETA_FILL,
                        edgecolor=THETA_EDGE, linewidth=1.0,
                        label="θ(t) added WAN latency" if i == 0 else None)
        ax.axhline(data["static"][i], color=INK_2, linewidth=1.2,
                   linestyle=(0, (4, 3)),
                   label="static t̂ (Table 1)" if i == 0 else None)
        ax.plot(times, data["t_hat"][:, i], color=SERIES[i % len(SERIES)],
                linewidth=2.0,
                label="adapted t̂ (DEMS-A window)" if i == 0 else None)
        ax.set_title(name, color=INK, fontsize=10, loc="left",
                     fontweight="bold")
    for ax in axes[max(0, n - ncols):n]:
        ax.set_xlabel("mission time [s]", color=INK_2, fontsize=9)
    for ax in axes[0:n:ncols]:
        ax.set_ylabel("latency [ms]", color=INK_2, fontsize=9)
    handles, labels = axes[0].get_legend_handles_labels()
    fig.legend(handles, labels, loc="lower center", ncol=3, frameon=False,
               fontsize=9, labelcolor=INK_2)
    fig.suptitle(f"Fig. 12 — {policy}: adapted cloud-latency estimate "
                 "t̂ vs θ(t), per model", color=INK, fontsize=12, x=0.01,
                 ha="left")
    fig.tight_layout(rect=(0, 0.06, 1, 0.95))
    out.parent.mkdir(parents=True, exist_ok=True)
    fig.savefig(out, dpi=144, facecolor=SURFACE)
    plt.close(fig)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--policy", default="DEMS-A",
                    help="an adaptive fleet policy (DEMS-A, GEMS-A, …)")
    ap.add_argument("--scenario", default=None,
                    help="registry scenario name (default: a trapezium "
                    "mission matching the Fig. 11 fleet runs)")
    ap.add_argument("--duration-ms", type=float, default=300_000.0)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--quick", action="store_true",
                    help="60 s mission (smoke)")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args()

    duration = 60_000.0 if args.quick else args.duration_ms
    if args.scenario:
        from repro.scenarios import get
        spec = get(args.scenario, duration_ms=duration)
    else:
        spec = trace_spec(duration)
    data = compute(spec, args.policy, args.seed)
    excess = data["t_hat"] - data["static"][None, :]
    out = render(data, args.policy, args.out)
    print(f"wrote {out}")
    print(f"t̂ inflation: peak +{excess.max():.0f} ms; "
          f"{100 * (excess.max(axis=1) > 1.0).mean():.0f}% of mission "
          "above static")


if __name__ == "__main__":
    main()
