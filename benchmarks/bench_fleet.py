"""Fleet-simulator perf benchmark → ``BENCH_fleet.json`` (perf trajectory).

    PYTHONPATH=src python benchmarks/bench_fleet.py            # full
    PYTHONPATH=src python benchmarks/bench_fleet.py --quick    # CI smoke
    PYTHONPATH=src python benchmarks/bench_fleet.py --quick \
        --check BENCH_fleet.json                               # CI gate

Four measurements:

* **tick throughput** — the steady-workload fleet program's edge-ticks
  per second, with compile time split out (first call − steady call);
* **sweep wall-clock** — the registry × policies × seeds evaluation run
  the old way (one ``run_fleet`` per scenario/policy/seed, compiles
  amortized only across same-shape runs) vs the padded one-program batch
  (``run_registry_sweep``: a single jit for the whole sweep).  The
  reported ``speedup`` is the headline number of the one-program-sweeps
  PR (target ≥2×); both phases start from cleared compilation caches so
  each pays its honest compile bill;
* **flight-recorder cost** — trace-on vs trace-off ticks/sec (< 15 %
  overhead target), XLA backend-compile accounting, a retrace guard on
  the policy-generic tick program, and the paper's tail scoreboard
  (p50/p95/p99 deadline slack & completion latency, windowed p95/p99
  deadline-hit rates, per-task-type QoE frequencies) for rush-hour,
  cloud-crunch, and the stochastic duration-jitter / heavy-tail
  scenarios;
* **metropolis scaling** — edge-ticks/sec at ``--edges 64,256,1024``
  fleet sizes through the donated double-buffered replay, and the
  shape-bucketed sweep planner vs the padded single-program reference
  (speedup target ≥1.3×, summaries bitwise equal); see
  ``docs/SCALING.md``.

``BENCH_fleet.json`` keeps one section per mode (``quick`` / ``full``),
so a committed quick-mode baseline gates CI runs apples-to-apples while
the full section documents the real trajectory numbers.  ``--check``
compares ``ticks_per_sec`` against the committed baseline's same-mode
section and exits 1 on a >25 % regression (tune with ``--tolerance``).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

# the one-program batch shards its replica axis over every available
# core (the loop, running one mission at a time, cannot) — expose the
# cores as host devices before jax initializes
_CORES = os.cpu_count() or 1
if _CORES > 1:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_CORES} "
        + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_fleet.json"


def _timed(fn) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def _clear_caches() -> None:
    from repro.sim import fleet_jax
    fleet_jax._fleet_program.cache_clear()
    jax.clear_caches()


def bench_throughput(quick: bool) -> dict:
    """Edge-ticks/sec of the steady paper workload (one compiled scan)."""
    from repro.core.task import PASSIVE, TABLE1
    from repro.sim.fleet_jax import default_signals, run_fleet

    models = [TABLE1[n] for n in PASSIVE]
    n_edges = 8 if quick else 16
    duration = 30_000.0 if quick else 120_000.0
    signals = default_signals(len(models), n_edges=n_edges,
                              duration_ms=duration)
    _clear_caches()
    run = lambda: run_fleet(models, "DEMS-A", signals)  # noqa: E731
    first = _timed(run)
    steady = min(_timed(run) for _ in range(2 if quick else 3))
    n_ticks = int(signals.times.shape[0])
    return dict(
        n_edges=n_edges, n_ticks=n_ticks, policy="DEMS-A",
        compile_s=round(first - steady, 3), wall_s=round(steady, 3),
        ticks_per_sec=round(n_ticks / steady, 1),
        edge_ticks_per_sec=round(n_ticks * n_edges / steady, 1))


def bench_sweep(quick: bool) -> dict:
    """Registry sweep: per-scenario loop vs the padded one-program batch."""
    from repro.scenarios import (fleet_summary, get, names,
                                 run_registry_sweep, run_scenario_fleet)

    duration = 10_000.0 if quick else 45_000.0
    policies = ("EDF-E+C", "DEMS", "DEMS-A") if quick else \
        ("EDF-E+C", "DEMS", "DEMS-A", "GEMS", "GEMS-COOP")
    seeds = (0, 1) if quick else (0, 1, 2)
    scenarios = names()

    _clear_caches()
    t0 = time.perf_counter()
    loop_rows = []
    for sc in scenarios:
        for pol in policies:
            for seed in seeds:
                spec = get(sc, duration_ms=duration, seed=seed)
                loop_rows.append(fleet_summary(
                    run_scenario_fleet(spec, pol)))
    loop_s = time.perf_counter() - t0

    _clear_caches()
    t0 = time.perf_counter()
    batch_rows = run_registry_sweep(scenarios, policies, seeds,
                                    duration_ms=duration, mesh="auto")
    batch_s = time.perf_counter() - t0

    mismatches = sum(
        any(row[k] != batch[k] for k in row)
        for row, batch in zip(loop_rows, batch_rows))
    return dict(
        n_runs=len(batch_rows), n_scenarios=len(scenarios),
        policies=list(policies), seeds=list(seeds),
        duration_ms=duration, batch_devices=jax.device_count(),
        loop_wall_s=round(loop_s, 2), batch_wall_s=round(batch_s, 2),
        speedup=round(loop_s / batch_s, 2), loop_vs_batch_mismatches=
        mismatches)


def bench_trace(quick: bool) -> dict:
    """Flight-recorder cost + the paper's tail scoreboard.

    Measures trace-on vs trace-off ticks/sec on the same steady
    workload as :func:`bench_throughput` (< 15 % overhead target — the
    trace-off program is bit-identical to pre-recorder, so only the
    trace-on number can move), counts real XLA backend compiles while
    both programs build, and verifies the tick program stayed
    policy-generic (one jit trace per cached program).  Also records
    p50/p95/p99 deadline-slack / completion-latency, windowed p95/p99
    deadline-hit rates, and per-task-type QoE frequencies for the
    rush-hour, cloud-crunch, duration-jitter, and heavy-tail scenarios.
    """
    from repro.core.task import PASSIVE, TABLE1
    from repro.obs import TraceSpec, metrics
    from repro.obs.prof import (CompileCounter, fleet_compile_stats,
                                reset_fleet_programs)
    from repro.scenarios import get, run_scenario_fleet
    from repro.sim.fleet_jax import default_signals, run_fleet

    models = [TABLE1[n] for n in PASSIVE]
    n_edges = 8 if quick else 16
    duration = 30_000.0 if quick else 120_000.0
    signals = default_signals(len(models), n_edges=n_edges,
                              duration_ms=duration)
    tspec = TraceSpec.full()
    reset_fleet_programs()
    jax.clear_caches()
    off = lambda: run_fleet(models, "DEMS-A", signals)          # noqa: E731
    on = lambda: run_fleet(models, "DEMS-A", signals,           # noqa: E731
                           trace=tspec)
    with CompileCounter() as cc:
        _timed(off)
        _timed(on)
    reps = 3 if quick else 5
    off_s = min(_timed(off) for _ in range(reps))
    on_s = min(_timed(on) for _ in range(reps))
    stats = fleet_compile_stats()
    n_ticks = int(signals.times.shape[0])

    tails = {}
    tail_duration = 15_000.0 if quick else 45_000.0
    for sc in ("rush-hour", "cloud-crunch", "duration-jitter",
               "heavy-tail"):
        spec = get(sc, duration_ms=tail_duration)
        res = run_scenario_fleet(spec, "DEMS-A", trace=tspec)
        metrics.check_conservation(res.counters)
        tm = metrics.tail_metrics(res.counters, tspec,
                                  list(spec.model_names))
        tails[sc] = dict(
            hit_rate=round(tm["hit_rate"], 4),
            deadline_hit={k: round(v, 4) if isinstance(v, float) else v
                          for k, v in tm["deadline_hit"].items()},
            slack_ms={p: round(v, 1) for p, v in tm["slack_ms"].items()},
            latency_ms={p: round(v, 1)
                        for p, v in tm["latency_ms"].items()},
            qoe_frequency={k: round(v, 4)
                           for k, v in tm["qoe_frequency"].items()},
            drops_by_cause=tm["drops_by_cause"])
    return dict(
        n_edges=n_edges, n_ticks=n_ticks, policy="DEMS-A",
        ticks_per_sec_off=round(n_ticks / off_s, 1),
        ticks_per_sec_on=round(n_ticks / on_s, 1),
        overhead_frac=round(on_s / off_s - 1.0, 4),
        backend_compiles=cc.count,
        compile_secs=round(cc.total_secs, 2),
        programs=stats.programs,
        max_traces_per_program=stats.max_traces_per_program,
        policy_generic=stats.policy_generic,
        tails=tails)


def bench_scaling(quick: bool, edges: tuple[int, ...]) -> dict:
    """Metropolis-scale section: edge-ticks/sec vs fleet size, plus the
    shape-bucketed sweep planner vs the padded reference.

    Two axes, both with bitwise parity guards:

    * **fleet-size scaling** — the steady workload at each ``--edges``
      size through the donated double-buffered replay
      (``run_fleet(donate=True, chunk_ticks=…)``), reporting
      edge-ticks/sec per size (target: near-linear growth) and checking
      the donated path equals the plain whole-horizon scan bitwise;
    * **registry sweep** — the full registry × the acceptance policy
      set, bucketed planner (donation on, per-bucket auto mesh) vs the
      padded single-program baseline, reporting the steady-state
      (warm-cache) wall-clock speedup (target ≥1.3×) with each
      planner's one-off compile bill split out, and counting summary
      mismatches (must be 0).
    """
    import numpy as np

    from repro.core.task import PASSIVE, TABLE1
    from repro.scenarios import run_registry_sweep
    from repro.sim.fleet_jax import default_signals, run_fleet

    models = [TABLE1[n] for n in PASSIVE]
    duration = 5_000.0 if quick else 10_000.0
    chunk = 64
    reps = 2
    rows = []
    for n_edges in edges:
        signals = default_signals(len(models), n_edges=n_edges,
                                  duration_ms=duration)
        _clear_caches()
        run = lambda: run_fleet(models, "DEMS-A", signals,   # noqa: E731
                                donate=True, chunk_ticks=chunk)
        first = _timed(run)
        steady = min(_timed(run) for _ in range(reps))
        n_ticks = int(signals.times.shape[0])
        rows.append(dict(
            n_edges=n_edges, n_ticks=n_ticks,
            compile_s=round(first - steady, 3), wall_s=round(steady, 3),
            ticks_per_sec=round(n_ticks / steady, 1),
            edge_ticks_per_sec=round(n_ticks * n_edges / steady, 1)))

    # donation parity at the smallest size: the donated double-buffered
    # replay must equal the plain whole-horizon scan bitwise
    sig0 = default_signals(len(models), n_edges=min(edges),
                           duration_ms=duration)
    plain = run_fleet(models, "DEMS-A", sig0)
    donated = run_fleet(models, "DEMS-A", sig0, donate=True,
                        chunk_ticks=chunk)
    parity_ok = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(donated)))

    # sweep planners: first call from cleared caches pays the compile
    # bill (reported split out — the bucketed planner traces one
    # program per shape bucket, the padded reference exactly one), the
    # steady call is the metropolis regime where long missions amortize
    # compiles to zero; the headline speedup compares steady walls
    policies = ("DEMS-A", "GEMS-B", "GEMS-COOP")
    seeds = (0,) if quick else (0, 1)
    sweep_duration = 10_000.0 if quick else 20_000.0

    def timed_sweep(planner, donate):
        _clear_caches()
        t0 = time.perf_counter()
        swept = run_registry_sweep(
            policies=policies, seeds=seeds, duration_ms=sweep_duration,
            mesh="auto", planner=planner, donate=donate)
        first = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_registry_sweep(
            policies=policies, seeds=seeds, duration_ms=sweep_duration,
            mesh="auto", planner=planner, donate=donate)
        steady = time.perf_counter() - t0
        return swept, first, steady

    bucketed_rows, bucketed_first, bucketed_s = timed_sweep(
        "bucketed", donate=True)
    padded_rows, padded_first, padded_s = timed_sweep(
        "padded", donate=False)
    mismatches = sum(
        any(b[k] != p[k] for k in b)
        for b, p in zip(bucketed_rows, padded_rows))
    return dict(
        policy="DEMS-A", duration_ms=duration, chunk_ticks=chunk,
        donation_parity_ok=parity_ok, edges=rows,
        sweep=dict(
            n_runs=len(bucketed_rows), policies=list(policies),
            seeds=list(seeds), duration_ms=sweep_duration,
            devices=jax.device_count(),
            bucketed_wall_s=round(bucketed_s, 2),
            bucketed_compile_s=round(bucketed_first - bucketed_s, 2),
            padded_wall_s=round(padded_s, 2),
            padded_compile_s=round(padded_first - padded_s, 2),
            speedup_vs_padded=round(padded_s / bucketed_s, 2),
            mismatches=mismatches))


def check(report: dict, baseline_path: pathlib.Path,
          tolerance: float) -> int:
    mode = "quick" if report["quick"] else "full"
    baseline = json.loads(baseline_path.read_text()).get(mode)
    if baseline is None:
        print(f"FAIL: baseline {baseline_path} has no {mode!r} section")
        return 1
    if "throughput" in report:
        want = baseline["throughput"]["ticks_per_sec"]
        got = report["throughput"]["ticks_per_sec"]
        floor = (1.0 - tolerance) * want
        print(f"ticks/sec: current {got}, baseline {want} "
              f"(floor {floor:.1f} at {tolerance:.0%} tolerance)")
        if got < floor:
            print("FAIL: per-tick throughput regressed beyond tolerance — "
                  "if intentional, regenerate BENCH_fleet.json")
            return 1
    if report.get("sweep", {}).get("loop_vs_batch_mismatches"):
        print("FAIL: one-program sweep summaries diverge from the "
              "per-scenario loop")
        return 1
    trace = report.get("trace")
    if trace is not None:
        print(f"trace overhead: {trace['overhead_frac']:+.1%} "
              f"({trace['ticks_per_sec_on']} on vs "
              f"{trace['ticks_per_sec_off']} off ticks/sec)")
        if not trace["policy_generic"]:
            print("FAIL: tick program retraced across policies "
                  "(PolicyParams leaked into a static argument)")
            return 1
    scaling = report.get("scaling")
    if scaling is not None:
        # exactness gates are hardware-free: the bucketed planner and
        # the donated replay must reproduce the padded reference bitwise
        if scaling["sweep"]["mismatches"]:
            print("FAIL: bucketed sweep summaries diverge from the "
                  "padded reference path")
            return 1
        if not scaling["donation_parity_ok"]:
            print("FAIL: donated double-buffered replay diverged from "
                  "the plain scan")
            return 1
        print(f"scaling: bucketed sweep "
              f"{scaling['sweep']['speedup_vs_padded']}x vs padded, "
              f"parity OK")
    print("OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short missions / fewer reps (CI smoke)")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--check", type=pathlib.Path, default=None,
                    help="baseline BENCH_fleet.json to gate against")
    ap.add_argument("--report", type=pathlib.Path, default=None,
                    help="with --check: gate a previously written report "
                    "file instead of re-measuring")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional ticks/sec regression")
    ap.add_argument("--edges", default=None,
                    help="comma-separated fleet sizes for the scaling "
                    "section (default: 64 quick, 64,256,1024 full)")
    ap.add_argument("--scaling-only", action="store_true",
                    help="measure only the scaling section and merge it "
                    "into the mode section in place (CI scaling-smoke)")
    args = ap.parse_args()

    if args.check is not None and args.report is not None:
        mode = "quick" if args.quick else "full"
        report = json.loads(args.report.read_text())[mode]
        sys.exit(check(report, args.check, args.tolerance))

    edges = tuple(int(x) for x in args.edges.split(",")) if args.edges \
        else ((64,) if args.quick else (64, 256, 1024))
    if args.scaling_only:
        report = dict(
            quick=args.quick,
            jax=jax.__version__, backend=jax.default_backend(),
            devices=jax.device_count(), cpus=os.cpu_count(),
            scaling=bench_scaling(args.quick, edges))
    else:
        report = dict(
            quick=args.quick,
            jax=jax.__version__, backend=jax.default_backend(),
            devices=jax.device_count(), cpus=os.cpu_count(),
            throughput=bench_throughput(args.quick),
            sweep=bench_sweep(args.quick),
            trace=bench_trace(args.quick),
            scaling=bench_scaling(args.quick, edges))
    print(json.dumps(report, indent=1))
    if args.check is not None:
        sys.exit(check(report, args.check, args.tolerance))
    merged = json.loads(args.out.read_text()) if args.out.exists() else {}
    mode = "quick" if args.quick else "full"
    if args.scaling_only:
        # refresh only the scaling subsection; sibling sections (and
        # their committed baselines) stay untouched
        merged.setdefault(mode, {})["scaling"] = report["scaling"]
    else:
        merged[mode] = report
    args.out.write_text(json.dumps(merged, indent=1, sort_keys=True) + "\n")
    print("wrote", args.out)


if __name__ == "__main__":
    main()
