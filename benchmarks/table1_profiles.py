"""Paper Table 1 / App. A-B: profile identities and sampler calibration.

Verifies γ columns and that the latency samplers reproduce the p95/p99
estimation methodology (edge actuals ≤ p99 estimate ~99 % of the time;
cloud actuals ≤ p95 estimate ~95 %).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Rows
from repro.core.task import ACTIVE, TABLE1
from repro.sim.network import CloudLatencyModel, EdgeLatencyModel


def main(quick: bool = False, rows: Rows | None = None) -> dict:
    rows = rows or Rows()
    rng = np.random.default_rng(0)
    em, cm = EdgeLatencyModel(), CloudLatencyModel(cold_start_p=0.0)
    n = 1000 if quick else 5000
    out = {}
    for name in ACTIVE:
        m = TABLE1[name]
        es = np.array([em.sample(rng, m.t_edge) for _ in range(n)])
        cs = np.array([cm.sample(rng, m.t_cloud, 0.0) for _ in range(n)])
        p_edge = float(np.mean(es <= m.t_edge))
        p_cloud = float(np.mean(cs <= m.t_cloud))
        out[name] = (p_edge, p_cloud)
        rows.add(f"table1/{name}", 0.0,
                 f"gammaE={m.gamma_edge} gammaC={m.gamma_cloud} "
                 f"P(edge<=t)={p_edge:.3f} P(cloud<=t_hat)={p_cloud:.3f}")
    return out


if __name__ == "__main__":
    rows = Rows()
    main(rows=rows)
    rows.emit()
