"""Benchmark harness entry point: one module per paper table/figure.

``python -m benchmarks.run``          — full runs (≈ paper durations)
``python -m benchmarks.run --quick``  — reduced sweep for CI

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import Rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run")
    args = ap.parse_args()

    from benchmarks import (fig8_baselines, fig10_incremental,
                            fig11_variability, fig13_scaling, fig14_gems,
                            table1_profiles, roofline_report)
    modules = {
        "table1": table1_profiles,
        "fig8": fig8_baselines,
        "fig10": fig10_incremental,
        "fig11": fig11_variability,
        "fig13": fig13_scaling,
        "fig14": fig14_gems,
        "roofline": roofline_report,
    }
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    rows = Rows()
    t0 = time.time()
    for name, mod in modules.items():
        t = time.time()
        try:
            mod.main(quick=args.quick, rows=rows)
            rows.add(f"{name}/elapsed_s", (time.time() - t) * 1e6,
                     f"{time.time() - t:.1f}s")
        except Exception as e:  # noqa: BLE001
            rows.add(f"{name}/ERROR", 0.0, f"{type(e).__name__}: {e}")
            print(f"[benchmark {name} failed: {e}]", file=sys.stderr)
    rows.add("total/elapsed_s", (time.time() - t0) * 1e6,
             f"{time.time() - t0:.1f}s")
    rows.emit()


if __name__ == "__main__":
    main()
