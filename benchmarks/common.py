"""Shared benchmark configuration: calibrated latency regimes + helpers.

Regimes (see EXPERIMENTS.md §Paper-claims for the calibration story):

* ``QOS``     — Table-1 workloads: edge actuals well under the p99
  estimates (that slack powers work stealing), long-tailed FaaS.
* ``GEMS_SLEEP`` — §8.7 semantics: execution replaced by sleep(expected),
  elastic warm cloud; the faithful GEMS/DEMS comparison.
* ``GEMS_STRESS`` — constrained cloud pool + bursty edge, the regime where
  queue-wait drops dominate and GEMS's rescheduling shows the largest QoE
  deltas.
"""
from __future__ import annotations

import time

from repro.sim.network import CloudLatencyModel, EdgeLatencyModel

QOS = dict(
    edge_model=EdgeLatencyModel(),           # mean 0.62×p99
    cloud_model=CloudLatencyModel(),         # lognormal, p95 ≈ t̂
    cloud_concurrency=16,
)

GEMS_SLEEP = dict(
    edge_model=EdgeLatencyModel(mean_frac=1.0, sd_frac=0.01, lo_frac=0.97,
                                hi_frac=1.02),
    cloud_model=CloudLatencyModel(median_frac=0.88, sigma=0.03,
                                  cold_start_p=0.0),
    cloud_concurrency=32,
)

GEMS_STRESS = dict(
    edge_model=EdgeLatencyModel(mean_frac=1.0, sd_frac=0.02, lo_frac=0.95,
                                hi_frac=1.1, spike_p=0.04, spike_mult=1.6),
    cloud_model=CloudLatencyModel(median_frac=0.92, sigma=0.06),
    cloud_concurrency=6,
)


class Rows:
    """Collects ``name,us_per_call,derived`` CSV rows."""

    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived: str) -> None:
        self.rows.append((name, us_per_call, derived))

    def emit(self) -> None:
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6
