"""Online-controller latency benchmark → ``controller`` section of
``BENCH_fleet.json``.

    PYTHONPATH=src python benchmarks/bench_serve.py            # full
    PYTHONPATH=src python benchmarks/bench_serve.py --quick    # CI smoke

Floods a :class:`repro.serve.controller.FleetController` with a
synthetic arrival storm — every (edge, model) cell of every tick
occupied, the densest signal the window builder can emit — and measures
the two latencies that bound the online control plane:

* **per-tick decision latency** — wall-clock of each jitted
  ``step_chunk`` window divided by its tick count (p50/p95/p99 over the
  run, warmup window excluded so the one-off compile is reported
  separately);
* **ingest-to-decision lag** — wall-clock from a tick's first
  ``submit()`` to the window step that scheduled it, as driven by a
  virtual-time :meth:`poll` cadence of one window.

The section lands next to ``throughput``/``sweep``/``trace`` in the
committed baseline (same ``quick``/``full`` mode split), so the serve
layer's latency trajectory is tracked alongside the simulator's
throughput.  ``--check`` gates on p95 per-tick latency regressing >2×
against the committed same-mode section (wall-clock tails on shared CI
runners are noisy; the gate is a guardrail against order-of-magnitude
rot, not a 25 % throughput gate like ``bench_fleet.py``).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_fleet.json"


def _pcts(samples) -> dict:
    a = np.asarray(samples, dtype=np.float64)
    if a.size == 0:
        return {f"p{q:g}": None for q in (50, 95, 99)}
    return {f"p{q:g}": round(float(np.percentile(a, q)), 4)
            for q in (50, 95, 99)}


def bench_controller(*, policy: str = "DEMS-A", n_edges: int = 4,
                     window_ticks: int = 8, duration_ms: float = 30_000.0,
                     dt: float = 25.0) -> dict:
    """Arrival-flood latency profile of one controller configuration."""
    from repro.scenarios.registry import get
    from repro.serve.controller import FleetController

    models = get("baseline").models
    ctl = FleetController(models, policy, n_edges=n_edges, dt=dt,
                          window_ticks=window_ticks)

    def flood(lo_ms: float, hi_ms: float) -> None:
        # worst-case storm: every (edge, model) cell of every tick fires
        t = lo_ms
        while t < hi_ms:
            for e in range(n_edges):
                for m in range(len(models)):
                    ctl.submit(t, e, m)
            t += dt

    # warmup: one window through the jit cache, timed as the compile bill
    w_ms = window_ticks * dt
    flood(0.0, w_ms)
    t0 = time.perf_counter()
    ctl.poll(w_ms)
    compile_s = time.perf_counter() - t0
    ctl.reset_latency_stats()

    now = w_ms
    while now < duration_ms:
        flood(now, now + w_ms)
        now += w_ms
        ctl.poll(now)
    ctl.close()

    steps = np.asarray(ctl.step_latencies_ms)
    snap = ctl.metrics_snapshot()
    return dict(
        policy=policy, n_edges=n_edges, n_models=len(models),
        window_ticks=window_ticks, dt_ms=dt,
        duration_ms=duration_ms, windows=int(ctl.windows_run),
        arrivals=int(snap["completed"] + snap["missed"] + snap["dropped"]),
        compile_s=round(compile_s, 3),
        per_tick_ms=_pcts(steps / window_ticks),
        step_ms=_pcts(steps),
        ingest_to_decision_ms=_pcts(ctl.ingest_lags_ms),
        completion_rate=round(snap["completion_rate"], 4))


def bench_backpressure(*, policy: str = "DEMS-A", n_edges: int = 2,
                       dt: float = 25.0, max_pending_ticks: int = 64,
                       n_submit: int = 5_000) -> dict:
    """Bounded-ingest stress: flood far past the pending bound with no
    polling at all and prove the controller sheds instead of growing
    without bound or deadlocking — every submission returns, accepted +
    shed accounts for all of them, and the buffer never exceeds the
    configured bound."""
    from repro.scenarios.registry import get
    from repro.serve.controller import FleetController

    models = get("baseline").models
    ctl = FleetController(models, policy, n_edges=n_edges, dt=dt,
                          max_pending_ticks=max_pending_ticks,
                          shed_policy="reject")
    t0 = time.perf_counter()
    accepted = 0
    for i in range(n_submit):
        accepted += ctl.submit(i * dt, i % n_edges, i % len(models)) >= 0
    wall_s = time.perf_counter() - t0
    return dict(max_pending_ticks=max_pending_ticks, submitted=n_submit,
                accepted=int(accepted), shed=int(ctl.shed_tasks),
                pending_ticks=int(ctl.builder.pending_ticks),
                wall_s=round(wall_s, 3))


def check_gate(section: dict, baseline_path, mode: str) -> int:
    """The ``--check`` CI gate as a testable function (exit-code style).

    Fails (returns 1) when p95 per-tick latency regressed >2× against
    the committed same-mode ``controller`` baseline, or when the
    bounded-backpressure invariants are violated: the ingest flood must
    be shed (not buffered unboundedly) and fully accounted for — a hang
    would never reach here, a leak shows up as accepted + shed != sent.
    """
    base = json.load(open(baseline_path)).get(mode, {}).get("controller")
    if base and base["per_tick_ms"]["p95"]:
        ratio = section["per_tick_ms"]["p95"] / base["per_tick_ms"]["p95"]
        print(f"p95 per-tick {section['per_tick_ms']['p95']} ms vs "
              f"baseline {base['per_tick_ms']['p95']} ms "
              f"({ratio:.2f}x)")
        if ratio > 2.0:
            print("FAIL: controller p95 per-tick latency regressed >2x")
            return 1
    else:
        print(f"no {mode}.controller baseline in {baseline_path}; skipped")
    bp = section["backpressure"]
    ok = (bp["shed"] > 0
          and bp["accepted"] + bp["shed"] == bp["submitted"]
          and bp["pending_ticks"] <= bp["max_pending_ticks"])
    print(f"backpressure: {bp['accepted']} accepted / {bp['shed']} "
          f"shed of {bp['submitted']}, "
          f"{bp['pending_ticks']}/{bp['max_pending_ticks']} "
          f"ticks pending")
    if not ok:
        print("FAIL: bounded-backpressure invariant violated")
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short flood (CI smoke): 2 edges, 10 s mission")
    ap.add_argument("--policy", default="DEMS-A")
    ap.add_argument("--out", default=str(DEFAULT_OUT),
                    help="BENCH json to merge the controller section into")
    ap.add_argument("--no-write", action="store_true",
                    help="print the section, leave the json untouched")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="gate: fail if p95 per-tick latency regressed "
                         ">2x vs this baseline's same-mode section")
    args = ap.parse_args(argv)

    kw = (dict(n_edges=2, duration_ms=10_000.0) if args.quick
          else dict(n_edges=4, duration_ms=30_000.0))
    section = bench_controller(policy=args.policy, **kw)
    section["backpressure"] = bench_backpressure(policy=args.policy)
    mode = "quick" if args.quick else "full"
    print(json.dumps({mode: {"controller": section}}, indent=2))

    if args.check:
        rc = check_gate(section, args.check, mode)
        if rc:
            return rc

    if not args.no_write:
        path = pathlib.Path(args.out)
        data = json.load(open(path)) if path.exists() else {}
        data.setdefault(mode, {})["controller"] = section
        path.write_text(json.dumps(data, indent=1, sort_keys=True) + "\n")
        print(f"wrote {mode}.controller -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
