"""Paper Fig. 13 weak scaling + beyond-paper SPMD fleet scaling.

(a) Paper-style: replicate independent edge simulators 7 → 28 edges (the
    paper's 1→4 host machines); per-edge utility/completion should stay
    flat.
(b) Beyond paper: the JAX fleet simulator steps 256 edges as ONE SPMD
    program (vmap + NamedSharding over the fleet axis) — city-scale
    emulation the Java platform cannot reach.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import QOS, Rows, timed
from repro.core.schedulers import make_policy
from repro.core.task import PASSIVE, TABLE1
from repro.sim.engine import run_policy
from repro.sim.fleet_jax import simulate_fleet
from repro.sim.workloads import standard


def main(quick: bool = False, rows: Rows | None = None) -> dict:
    rows = rows or Rows()
    duration = 120_000.0 if quick else 300_000.0
    out = {}

    # (a) replicated discrete-event edges (3D-P per edge, like the paper)
    for n_edges in ((7,) if quick else (7, 14, 28)):
        results = []
        for e in range(n_edges):
            arrivals = standard("3D-P", duration_ms=duration, seed=100 + e)
            r, us = timed(lambda: run_policy(
                make_policy("DEMS"), arrivals, duration, seed=e, **QOS))
            results.append(r)
        comp = np.mean([r.completion_rate for r in results])
        util = np.mean([r.qos_utility for r in results])
        out[n_edges] = (comp, util)
        rows.add(f"fig13/event_sim/{n_edges}edges", us,
                 f"completed={100 * comp:.1f}% qos/edge={util:.0f} "
                 f"(paper: ~83% flat)")

    # (b) one SPMD program over the fleet
    models = [TABLE1[n] for n in PASSIVE]
    n_fleet = 32 if quick else 256
    final, us = timed(lambda: simulate_fleet(
        models, "DEMS", n_edges=n_fleet, drones_per_edge=3,
        duration_ms=min(duration, 120_000.0)))
    succ = np.asarray(final.n_success).sum()
    gen = n_fleet * 3 * int(min(duration, 120_000.0) / 1000) * len(models)
    rows.add(f"fig13/fleet_spmd/{n_fleet}edges", us,
             f"completed={100 * succ / gen:.1f}% "
             f"({succ:.0f}/{gen} tasks in one jitted program)")
    out["fleet"] = (n_fleet, succ, gen)
    return out


if __name__ == "__main__":
    rows = Rows()
    main(rows=rows)
    rows.emit()
