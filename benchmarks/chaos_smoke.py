"""CI chaos smoke: one hostile scenario end-to-end, streamed.

    PYTHONPATH=src python benchmarks/chaos_smoke.py --out scoreboard.json

Builds a hostile mission — the baseline workload with an edge scheduler
crash *and* a correlated cloud brownout injected — and drives it through
the streaming control plane, asserting the three chaos-engine
guarantees end-to-end:

1. **streaming equivalence under faults** — a
   :class:`repro.serve.controller.FleetController` fed the compiled
   fault lanes window-by-window finishes in the bitwise-identical
   ``EdgeState`` as one replay call (crashes and brownouts do not break
   the scan-composition contract);
2. **exact conservation** — the flight-recorder ledger
   ``arrived = settled + in-flight`` balances on every tick through the
   crash window (flushed tasks are *settled as drops*, never leaked);
3. **degradation scoreboard** — the quick retention scoreboard for two
   hostile registry scenarios is computed and written to ``--out`` as
   the uploadable CI artifact.

Exit code is non-zero on any violation.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="chaos_scoreboard.json",
                    help="degradation scoreboard artifact path")
    ap.add_argument("--duration", type=float, default=45_000.0)
    args = ap.parse_args(argv)

    from bench_degradation import check_section, run_degradation
    from repro.faults import Brownout, EdgeCrash, FaultSpec
    from repro.obs.metrics import check_conservation, tail_metrics
    from repro.obs.trace import TraceSpec
    from repro.scenarios.registry import get
    from repro.scenarios.runner import (assert_streaming_equivalence,
                                        run_scenario_fleet)

    d = args.duration
    spec = dataclasses.replace(
        get("baseline", duration_ms=d),
        name="chaos-smoke",
        faults=FaultSpec(
            crashes=(EdgeCrash(edge=0, start_ms=0.2 * d, end_ms=0.5 * d),),
            brownouts=(Brownout(start_ms=0.1 * d, end_ms=0.9 * d,
                                theta_ms=300.0, ramp_ms=0.2 * d),)))

    print("1/3 streaming equivalence under edge crash + brownout …")
    summary = assert_streaming_equivalence(spec, "DEMS-A")
    print(f"    bitwise OK: {summary}")

    print("2/3 conservation ledger through the crash window …")
    trace = TraceSpec(counters=True)
    res = run_scenario_fleet(spec, "DEMS-A", trace=trace)
    check_conservation(res.counters)
    tail = tail_metrics(res.counters, trace)
    print(f"    exact; drops by cause: {tail['drops_by_cause']}")
    if tail["drops_by_cause"]["crash"] == 0:
        print("FAIL: crash window injected but no crash-flush drops "
              "recorded — fault lanes not reaching the tick program")
        return 1

    print("3/3 degradation scoreboard (quick) …")
    section = run_degradation(scenarios=("ddos-flood", "brownout"),
                              policies=("DEMS-A", "GEMS-COOP"),
                              duration_ms=d)
    bad = check_section(section)
    for b in bad:
        print(f"FAIL: {b}")
    if bad:
        return 1
    with open(args.out, "w") as f:
        json.dump(dict(quick=dict(degradation=section)), f, indent=2)
    print(f"    wrote scoreboard -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
