"""Sweep scenario × policy × seed and report completion rate / QoS / QoE.

    PYTHONPATH=src python benchmarks/scenarios_sweep.py \
        --backend oracle --duration-ms 120000
    PYTHONPATH=src python benchmarks/scenarios_sweep.py \
        --backend fleet --policies DEMS DEMS-A GEMS-COOP --seeds 0 1 2
    PYTHONPATH=src python benchmarks/scenarios_sweep.py --quick

Oracle rows carry the full event-driven metric set (windows, stealing,
migration); fleet rows add the cross-edge peer-offload count.  The fleet
backend runs the **whole sweep as one compiled program**: scenarios are
padded to a common shape and policies are runtime parameters
(`run_registry_sweep`), so scenarios × policies × seeds cost a single
jit, not one per (scenario, policy).  Output is CSV on stdout, one row
per (scenario, policy, seed).  ``--quick`` is the CI smoke path: one
calm and one congested short scenario on both backends.
"""
from __future__ import annotations

import argparse

from repro.scenarios import get, names, run_registry_sweep, \
    run_scenario_oracle

ORACLE_POLICIES = ("EDF-E+C", "DEMS", "GEMS")
FLEET_POLICIES = ("EDF", "HPF", "CLD", "EDF-E+C", "SJF-E+C", "SOTA1",
                  "SOTA2", "DEMS", "DEMS-A", "DEMS-COOP", "GEMS",
                  "GEMS-A", "GEMS-COOP", "GEMS-B")


def sweep_oracle(scenarios, policies, duration_ms) -> None:
    print("scenario,policy,generated,completed,completion_rate,"
          "qos_utility,qoe_utility,stolen,migrated,gems_rescheduled")
    for sc in scenarios:
        spec = get(sc, duration_ms=duration_ms) if duration_ms else get(sc)
        for pol in policies:
            r = run_scenario_oracle(spec, pol).merged
            print(f"{sc},{pol},{r.generated},{r.completed},"
                  f"{r.completion_rate:.4f},{r.qos_utility:.0f},"
                  f"{r.qoe_utility:.0f},{r.stolen},{r.migrated},"
                  f"{r.gems_rescheduled}")


def sweep_fleet(scenarios, policies, duration_ms, dt, seeds) -> None:
    print("scenario,policy,seed,completed,completion_rate,qos_utility,"
          "qoe_utility,stolen,peer_offloaded")
    rows = run_registry_sweep(tuple(scenarios), tuple(policies),
                              tuple(seeds), dt=dt, duration_ms=duration_ms)
    for s in rows:
        print(f"{s['scenario']},{s['policy']},{s['seed']},{s['completed']},"
              f"{s['completion_rate']:.4f},{s['qos_utility']:.0f},"
              f"{s['qoe_utility']:.0f},{s['stolen']},"
              f"{s['peer_offloaded']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="oracle",
                    choices=("oracle", "fleet"))
    ap.add_argument("--scenarios", nargs="*", default=list(names()))
    ap.add_argument("--policies", nargs="*", default=None)
    ap.add_argument("--seeds", nargs="*", type=int, default=[0],
                    help="fleet backend: batched one-jit seed sweep")
    ap.add_argument("--duration-ms", type=float, default=None)
    ap.add_argument("--dt", type=float, default=25.0)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: one short scenario, both backends")
    args = ap.parse_args()

    if args.quick:
        # one calm and one congested scenario so neither the elastic-limit
        # nor the finite-pool/bw-shaping path can rot; SOTA2 + GEMS-B keep
        # the newly-covered routing/winnability branches in the smoke
        sweep_oracle(("baseline", "cloud-crunch"), ("DEMS",), 20_000.0)
        sweep_fleet(("baseline", "cloud-crunch"),
                    ("DEMS", "DEMS-A", "SOTA2", "GEMS-B"),
                    20_000.0, args.dt, (0, 1))
        return
    if args.backend == "oracle":
        sweep_oracle(args.scenarios, args.policies or ORACLE_POLICIES,
                     args.duration_ms)
    else:
        sweep_fleet(args.scenarios, args.policies or FLEET_POLICIES,
                    args.duration_ms, args.dt, args.seeds)


if __name__ == "__main__":
    main()
