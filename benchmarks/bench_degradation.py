"""Graceful-degradation scoreboard → ``degradation`` section of
``BENCH_fleet.json``.

    PYTHONPATH=src python benchmarks/bench_degradation.py            # full
    PYTHONPATH=src python benchmarks/bench_degradation.py --quick    # CI

For each hostile registry scenario (``flash-crowd``, ``ddos-flood``,
``partition``, ``brownout``) and each policy, runs the fleet simulator
twice — once with the scenario's fault schedule, once with its
fault-free twin (``faults=None``, same drones/bursts/seed) — and
reports **retention**: the fraction of fault-free QoS utility, QoE
utility and completion rate the policy still earns under the injected
faults.  Retention is the paper-facing robustness number: a policy that
degrades gracefully keeps most of its utility through a crash or
brownout instead of collapsing.

Every hostile run is executed with the flight recorder on and the
conservation ledger (``arrived = settled + in-flight``) is asserted
exactly — a leaking ledger fails the benchmark regardless of scores.

``--check`` re-validates the scoreboard invariants (every retention is
a finite number, every ledger balanced) and exits non-zero on
violation; ``--out`` merges the section into the committed baseline
next to ``throughput``/``controller``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_fleet.json"

HOSTILE = ("flash-crowd", "ddos-flood", "partition", "brownout")


def _ratio(num: float, den: float) -> float | None:
    """Retention num/den; None when the baseline earned nothing."""
    if den == 0.0:
        return None
    return round(num / den, 4)


def run_degradation(*, scenarios=HOSTILE,
                    policies=("DEMS-A", "GEMS-COOP"),
                    duration_ms: float = 120_000.0,
                    dt: float = 25.0) -> dict:
    """Per-(scenario, policy) retention vs the fault-free twin."""
    from repro.obs.metrics import check_conservation
    from repro.obs.trace import TraceSpec
    from repro.scenarios.registry import get
    from repro.scenarios.runner import fleet_summary, run_scenario_fleet

    trace = TraceSpec(counters=True)
    out: dict = {}
    for name in scenarios:
        spec = get(name, duration_ms=duration_ms)
        if spec.faults is None:
            raise ValueError(f"scenario {name!r} has no fault schedule")
        calm = dataclasses.replace(spec, faults=None)
        out[name] = {}
        for policy in policies:
            res = run_scenario_fleet(spec, policy, dt=dt, trace=trace)
            check_conservation(res.counters)
            hostile = fleet_summary(res.final)
            base = fleet_summary(run_scenario_fleet(calm, policy, dt=dt))
            out[name][policy] = dict(
                qos=round(hostile["qos_utility"], 1),
                qoe=round(hostile["qoe_utility"], 1),
                completion_rate=round(hostile["completion_rate"], 4),
                dropped=hostile["dropped"],
                qos_retention=_ratio(hostile["qos_utility"],
                                     base["qos_utility"]),
                qoe_retention=_ratio(hostile["qoe_utility"],
                                     base["qoe_utility"]),
                completion_retention=_ratio(hostile["completion_rate"],
                                            base["completion_rate"]),
                conservation="exact")
    return dict(duration_ms=duration_ms, scenarios=out)


def check_section(section: dict) -> list[str]:
    """Scoreboard invariants; returns human-readable violations."""
    bad = []
    for name, by_policy in section["scenarios"].items():
        for policy, row in by_policy.items():
            if row.get("conservation") != "exact":
                bad.append(f"{name}/{policy}: ledger not exact")
            for key in ("qos_retention", "completion_retention"):
                v = row.get(key)
                if v is None or not (v == v and abs(v) < 1e6):
                    bad.append(f"{name}/{policy}: {key} is {v!r}")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="45 s missions, 2 policies (CI smoke)")
    ap.add_argument("--scenarios", nargs="*", default=None,
                    help=f"hostile scenarios to score (default {HOSTILE})")
    ap.add_argument("--out", default=str(DEFAULT_OUT),
                    help="BENCH json to merge the degradation section into")
    ap.add_argument("--no-write", action="store_true",
                    help="print the section, leave the json untouched")
    ap.add_argument("--check", action="store_true",
                    help="gate: fail on non-finite retention or a "
                         "leaking conservation ledger")
    args = ap.parse_args(argv)

    kw = dict(duration_ms=45_000.0, policies=("DEMS-A", "GEMS-COOP")) \
        if args.quick else dict(
            duration_ms=120_000.0,
            policies=("DEMS-A", "GEMS-COOP", "SJF-E+C", "GEMS-B"))
    if args.scenarios:
        kw["scenarios"] = tuple(args.scenarios)
    section = run_degradation(**kw)
    mode = "quick" if args.quick else "full"
    print(json.dumps({mode: {"degradation": section}}, indent=2))

    if args.check:
        bad = check_section(section)
        for b in bad:
            print(f"FAIL: {b}")
        if bad:
            return 1
        print("degradation scoreboard invariants hold")

    if not args.no_write:
        path = pathlib.Path(args.out)
        data = json.load(open(path)) if path.exists() else {}
        data.setdefault(mode, {})["degradation"] = section
        path.write_text(json.dumps(data, indent=1, sort_keys=True) + "\n")
        print(f"wrote {mode}.degradation -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
