"""Field-validation analogue (§8.8): live scheduling of real JAX models.

Three reduced zoo models play the roles of the Ocularone DNNs — HV
(hazard-vest tracking, 10 FPS, tight deadline), DEV (distance estimation,
5 FPS), BP (body pose, 5 FPS; negative cloud utility like the paper's BP).
Each task is an actual jitted forward pass; the cloud path pays a shaped
network delay.  GEMS vs Edge-Only vs E+C, 20 s wall-clock each.

    PYTHONPATH=src python examples/serve_fleet.py --duration 20
"""
import argparse
import dataclasses

import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.schedulers import make_policy
from repro.core.task import ModelProfile
from repro.serve.engine import ServableModel, ServeEngine, run_stream


def calibrate(run, n=30) -> float:
    import time
    ts = []
    for _ in range(n):
        t0 = time.monotonic()
        run()
        ts.append((time.monotonic() - t0) * 1e3)
    return float(np.percentile(ts, 95))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=20.0, help="seconds")
    ap.add_argument("--policies", default="EDF,EDF-E+C,GEMS")
    args = ap.parse_args()

    # role → (zoo family source, edge load share, deadline×t95, β, K, K̂)
    roles = {
        "HV": ("starcoder2-3b", 0.7, 3.0, 125, 1, 25),
        "DEV": ("granite-3-2b", 0.4, 5.0, 100, 1, 26),
        "BP": ("xlstm-1.3b", 0.3, 8.0, 40, 2, 43),   # γ^C < 0 → edge-only
    }
    models, fps = {}, {}
    for name, (arch, share, dl_mult, beta, ke, kc) in roles.items():
        cfg = reduced(ARCHS[arch], n_layers=2, d_model=192, vocab=512)
        prof = ModelProfile(name=name, beta=beta, deadline=1.0, t_edge=1.0,
                            t_cloud=1.0, cost_edge=ke, cost_cloud=kc,
                            qoe_beta=100.0, qoe_alpha=0.9,
                            qoe_window=5_000.0)
        sm = ServableModel.from_arch(prof, cfg, batch=1, seq=64)
        t95 = calibrate(sm.run)
        # load-calibrate: total demand ≈ 1.4× edge capacity so the
        # scheduler actually has decisions to make on this CPU
        fps[name] = min(60.0, share * 1000.0 / t95)
        prof = dataclasses.replace(prof, deadline=dl_mult * t95 + 30.0,
                                   t_edge=t95,
                                   t_cloud=t95 * 0.7 + 60.0)
        models[name] = dataclasses.replace(sm, profile=prof)
        print(f"{name:4s} ({arch}): edge p95 {t95:.1f} ms, cloud est "
              f"{prof.t_cloud:.1f} ms, deadline {prof.deadline:.0f} ms, "
              f"{fps[name]:.1f} FPS")
    duration_ms = args.duration * 1e3
    print()
    for pol in args.policies.split(","):
        engine = ServeEngine(make_policy(pol), dict(models),
                             cloud_concurrency=4, seed=0)
        # fresh stats per run
        r = run_stream(engine, fps, duration_ms)
        print(r.summary())
    print("\nGEMS keeps per-model completion-rate windows healthy by "
          "preemptively pushing lagging models' queued tasks to the cloud "
          "(paper §8.8: 48% more tasks than edge-only at 15 FPS).")


if __name__ == "__main__":
    main()
