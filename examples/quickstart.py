"""Quickstart: schedule a drone fleet's inference tasks with DEMS.

Runs the paper's 3-drone Active workload (6 DNN profiles from Table 1)
through four schedulers and prints the QoS comparison — ~5 s on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.schedulers import make_policy
from repro.sim.engine import run_policy
from repro.sim.workloads import standard

arrivals = standard("3D-A", seed=1)      # 5400 tasks over 300 s
print(f"{len(arrivals)} inference tasks from 3 drones × 6 DNN models\n")

for policy in ("EDF", "CLD", "EDF-E+C", "DEMS"):
    result = run_policy(make_policy(policy), arrivals, 300_000.0, seed=42)
    print(result.summary())

print("\nDEMS balances on-time completion against utility: it keeps the "
      "captive edge saturated (work stealing pulls BP tasks back from the "
      "cloud queue), migrates displaced tasks by Eqn-3 score, and only "
      "pays for FaaS calls that actually help.")
