"""Run a named scenario through the oracle and/or the JAX fleet simulator.

    PYTHONPATH=src python examples/run_scenario.py --scenario rush-hour \
        --policy DEMS --backend both
    PYTHONPATH=src python examples/run_scenario.py --scenario flaky-cloud \
        --policy DEMS-A --backend fleet --seeds 0 1 2
    PYTHONPATH=src python examples/run_scenario.py --scenario hetero-edges \
        --policy DEMS --backend fleet --cooperation

``--cooperation`` enables the cross-edge peer-offload exchange (fleet
backend only; the oracle runs edges as silos).  Passing more than one
``--seeds`` value runs the fleet backend's whole seed sweep as a single
compiled program (``run_fleet_batch``).
"""
from __future__ import annotations

import argparse

from repro.core.schedulers import ALL_POLICIES
from repro.scenarios import (fleet_summary, fleet_summary_batch, get, names,
                             run_scenario_fleet, run_scenario_fleet_batch,
                             run_scenario_oracle)
from repro.sim.fleet_jax import FleetPolicy


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="baseline", choices=names())
    ap.add_argument("--policy", default="DEMS")
    ap.add_argument("--backend", default="both",
                    choices=("oracle", "fleet", "both"))
    ap.add_argument("--duration-ms", type=float, default=None,
                    help="override the scenario's mission duration")
    ap.add_argument("--cooperation", action="store_true",
                    help="cross-edge peer offload (fleet backend)")
    ap.add_argument("--seeds", nargs="*", type=int, default=None,
                    help=">1 seed: one-jit batched fleet sweep")
    ap.add_argument("--dt", type=float, default=25.0)
    args = ap.parse_args()

    overrides = {}
    if args.duration_ms is not None:
        overrides["duration_ms"] = args.duration_ms
    spec = get(args.scenario, **overrides)
    print(f"scenario={spec.name} edges={spec.n_edges} drones={spec.n_drones}"
          f" models={','.join(spec.model_names)}"
          f" duration={spec.duration_ms / 1000:.0f}s")

    if args.backend in ("oracle", "both"):
        if args.policy not in ALL_POLICIES:
            ap.error(f"--policy {args.policy!r} unknown to the oracle; "
                     f"choose from {ALL_POLICIES}")
        run = run_scenario_oracle(spec, args.policy)
        print("oracle  ", run.merged.summary())
        for e, r in enumerate(run.per_edge):
            print(f"  edge{e} tasks={r.completed}/{r.generated} "
                  f"QoS={r.qos_utility:.0f} util="
                  f"{100 * r.edge_utilization:.0f}%")

    if args.backend in ("fleet", "both"):
        try:
            pol = FleetPolicy.from_name(args.policy)
        except ValueError as e:
            ap.error(str(e))
        if args.cooperation:
            import dataclasses
            pol = dataclasses.replace(pol, cooperation=True)
        if args.seeds and len(args.seeds) > 1:
            final = run_scenario_fleet_batch(spec, pol, tuple(args.seeds),
                                             dt=args.dt)
            for seed, s in zip(args.seeds, fleet_summary_batch(final)):
                print(f"fleet[s{seed}] tasks={s['completed']} "
                      f"({100 * s['completion_rate']:.1f}% of settled) "
                      f"QoS={s['qos_utility']:.0f} "
                      f"QoE={s['qoe_utility']:.0f} stolen={s['stolen']}")
            return
        if args.seeds:
            spec = get(args.scenario, seed=args.seeds[0], **overrides)
        final = run_scenario_fleet(spec, pol, dt=args.dt)
        s = fleet_summary(final)
        print(f"fleet    tasks={s['completed']} "
              f"({100 * s['completion_rate']:.1f}% of settled) "
              f"QoS={s['qos_utility']:.0f} QoE={s['qoe_utility']:.0f} "
              f"stolen={s['stolen']} peer_offloaded={s['peer_offloaded']}")


if __name__ == "__main__":
    main()
