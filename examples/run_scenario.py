"""Run a named scenario through the oracle and/or the JAX fleet simulator.

    PYTHONPATH=src python examples/run_scenario.py --scenario rush-hour \
        --policy DEMS --backend both
    PYTHONPATH=src python examples/run_scenario.py --scenario flaky-cloud \
        --policy DEMS-A --backend fleet --seeds 0 1 2
    PYTHONPATH=src python examples/run_scenario.py --scenario hetero-edges \
        --policy DEMS --backend fleet --cooperation

``--cooperation`` enables the cross-edge peer-offload exchange on the
fleet backend; a ``*-COOP`` policy name enables it on both backends
(the oracle runs the lockstep multi-edge ``FleetOracle``).  Passing more than one
``--seeds`` value runs the fleet backend's whole seed sweep as a single
compiled program (``run_fleet_batch``).  ``--trace`` turns on the
flight recorder (fleet backend, single run) and prints the tail
scoreboard — p50/p95/p99 deadline slack and completion latency,
per-task-type QoE success frequencies, drops by cause — plus the task
conservation residual (always 0).
"""
from __future__ import annotations

import argparse

from repro.core.schedulers import ALL_POLICIES
from repro.scenarios import (fleet_summary, fleet_summary_batch, get, names,
                             run_scenario_fleet, run_scenario_fleet_batch,
                             run_scenario_oracle)
from repro.sim.fleet_jax import FleetPolicy


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="baseline", choices=names())
    ap.add_argument("--policy", default="DEMS")
    ap.add_argument("--backend", default="both",
                    choices=("oracle", "fleet", "both"))
    ap.add_argument("--duration-ms", type=float, default=None,
                    help="override the scenario's mission duration")
    ap.add_argument("--cooperation", action="store_true",
                    help="cross-edge peer offload (fleet backend)")
    ap.add_argument("--seeds", nargs="*", type=int, default=None,
                    help=">1 seed: one-jit batched fleet sweep")
    ap.add_argument("--dt", type=float, default=25.0)
    ap.add_argument("--trace", action="store_true",
                    help="flight recorder: tail metrics + conservation "
                         "ledger (fleet backend)")
    args = ap.parse_args()

    overrides = {}
    if args.duration_ms is not None:
        overrides["duration_ms"] = args.duration_ms
    spec = get(args.scenario, **overrides)
    print(f"scenario={spec.name} edges={spec.n_edges} drones={spec.n_drones}"
          f" models={','.join(spec.model_names)}"
          f" duration={spec.duration_ms / 1000:.0f}s")

    if args.backend in ("oracle", "both"):
        base = args.policy[:-5] if args.policy.endswith("-COOP") \
            else args.policy
        if base not in ALL_POLICIES:
            ap.error(f"--policy {args.policy!r} unknown to the oracle; "
                     f"choose from {ALL_POLICIES} (plus '-COOP' variants)")
        run = run_scenario_oracle(spec, args.policy)
        print("oracle  ", run.merged.summary())
        for e, r in enumerate(run.per_edge):
            print(f"  edge{e} tasks={r.completed}/{r.generated} "
                  f"QoS={r.qos_utility:.0f} util="
                  f"{100 * r.edge_utilization:.0f}%")

    if args.backend in ("fleet", "both"):
        try:
            pol = FleetPolicy.from_name(args.policy)
        except ValueError as e:
            ap.error(str(e))
        if args.cooperation:
            import dataclasses
            pol = dataclasses.replace(pol, cooperation=True)
        if args.seeds and len(args.seeds) > 1:
            final = run_scenario_fleet_batch(spec, pol, tuple(args.seeds),
                                             dt=args.dt)
            for seed, s in zip(args.seeds, fleet_summary_batch(final)):
                print(f"fleet[s{seed}] tasks={s['completed']} "
                      f"({100 * s['completion_rate']:.1f}% of settled) "
                      f"QoS={s['qos_utility']:.0f} "
                      f"QoE={s['qoe_utility']:.0f} stolen={s['stolen']}")
            return
        if args.seeds:
            spec = get(args.scenario, seed=args.seeds[0], **overrides)
        tspec = None
        if args.trace:
            from repro.obs import TraceSpec
            tspec = TraceSpec.full()
        res = run_scenario_fleet(spec, pol, dt=args.dt, trace=tspec)
        final = res.final if tspec else res
        s = fleet_summary(final)
        print(f"fleet    tasks={s['completed']} "
              f"({100 * s['completion_rate']:.1f}% of settled) "
              f"QoS={s['qos_utility']:.0f} QoE={s['qoe_utility']:.0f} "
              f"stolen={s['stolen']} peer_offloaded={s['peer_offloaded']}")
        if tspec:
            import numpy as np

            from repro.obs import metrics
            tm = metrics.tail_metrics(res.counters, tspec,
                                      list(spec.model_names))
            resid = metrics.conservation_ledger(
                res.counters)["residual"]
            print(f"trace    hit_rate={100 * tm['hit_rate']:.1f}% "
                  f"slack p50/p95/p99 = "
                  f"{tm['slack_ms']['p50']:.0f}/"
                  f"{tm['slack_ms']['p95']:.0f}/"
                  f"{tm['slack_ms']['p99']:.0f} ms  latency = "
                  f"{tm['latency_ms']['p50']:.0f}/"
                  f"{tm['latency_ms']['p95']:.0f}/"
                  f"{tm['latency_ms']['p99']:.0f} ms")
            dh = tm["deadline_hit"]
            print(f"         deadline-hit tail (~1s windows): "
                  f"mean={100 * dh['mean']:.1f}% "
                  f"p95={100 * dh['p95']:.1f}% "
                  f"p99={100 * dh['p99']:.1f}%")
            print(f"         QoE freq: " + "  ".join(
                f"{k}={100 * v:.0f}%"
                for k, v in tm['qoe_frequency'].items()))
            print(f"         drops: {tm['drops_by_cause']}  "
                  f"conservation residual max="
                  f"{int(np.abs(resid).max())}")


if __name__ == "__main__":
    main()
