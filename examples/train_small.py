"""End-to-end training driver: a ~small LM for a few hundred steps on CPU.

Trains the reduced granite-3-2b family config on the synthetic Markov-LM
data pipeline with the pure-JAX AdamW, checkpoints, restores, and verifies
the loss went down.  Pass ``--arch`` for any of the 10 zoo families and
``--steps`` to train longer.

    PYTHONPATH=src python examples/train_small.py --steps 300
"""
import argparse

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.train.loop import train
from repro.train import checkpoint as ckpt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt/train_small")
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch], n_layers=2, d_model=128, vocab=512)
    print(f"training reduced {args.arch} ({cfg.family}) for "
          f"{args.steps} steps")
    state, losses = train(cfg, steps=args.steps, batch=args.batch,
                          seq_len=args.seq, checkpoint_path=args.ckpt)

    first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
    print(f"\nloss {first:.3f} → {last:.3f} "
          f"({'LEARNED' if last < first - 0.1 else 'no improvement?'})")

    restored = ckpt.load(args.ckpt, state.params)
    print("checkpoint restored:",
          all((a == b).all() for a, b in zip(
              __import__('jax').tree.leaves(restored),
              __import__('jax').tree.leaves(state.params))))


if __name__ == "__main__":
    main()
