"""DEMS-A adaptation demo (§5.4 / Fig. 12): watch the cloud-latency
estimate track a trapezium latency wave, skip unviable tasks, and recover
after the cooling period.

    PYTHONPATH=src python examples/adapt_variability.py
"""
from repro.core.schedulers import make_policy
from repro.sim.engine import Simulator
from repro.sim.network import CloudLatencyModel, trapezium
from repro.sim.workloads import standard

arrivals = standard("4D-P", seed=1)
cm = CloudLatencyModel(latency_at=trapezium(high=400.0))

for name in ("DEMS", "DEMS-A"):
    sim = Simulator(make_policy(name), arrivals, 300_000.0, seed=5,
                    cloud_model=cm)
    r = sim.run()
    print(r.summary())
    if name == "DEMS-A":
        est = sim.adaptive["DEV"]
        print(f"  DEV cloud estimate ended at {est.current:.0f} ms "
              f"(static {est.static:.0f} ms)")

print("\nDEMS-A inflates each model's expected cloud latency from a "
      "sliding window of observations, stops sending doomed tasks during "
      "the 400 ms wave, and re-probes after the 10 s cooling period — "
      "the paper reports +16–27% QoS utility under shaping, reproduced "
      "in benchmarks/fig11_variability.py.")
